//! Typed host tensor storage.

use super::DType;

/// Typed storage backing a [`Tensor`].
#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    U8(Vec<u8>),
    U16(Vec<u16>),
    I32(Vec<i32>),
    F32(Vec<f32>),
    F64(Vec<f64>),
}

/// A host tensor: shape + typed row-major data. The unit of data exchanged
/// with the runtime (marshaled to XLA literals at the executor boundary).
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: TensorData,
}

macro_rules! ctor {
    ($fn_name:ident, $t:ty, $variant:ident) => {
        pub fn $fn_name(data: &[$t], shape: &[usize]) -> Tensor {
            assert_eq!(
                data.len(),
                shape.iter().product::<usize>(),
                "data length does not match shape {:?}",
                shape
            );
            Tensor { shape: shape.to_vec(), data: TensorData::$variant(data.to_vec()) }
        }
    };
}

macro_rules! getter {
    ($fn_name:ident, $t:ty, $variant:ident) => {
        pub fn $fn_name(&self) -> Option<&[$t]> {
            match &self.data {
                TensorData::$variant(v) => Some(v),
                _ => None,
            }
        }
    };
}

impl Tensor {
    ctor!(from_u8, u8, U8);
    ctor!(from_u16, u16, U16);
    ctor!(from_i32, i32, I32);
    ctor!(from_f32, f32, F32);
    ctor!(from_f64, f64, F64);

    getter!(as_u8, u8, U8);
    getter!(as_u16, u16, U16);
    getter!(as_i32, i32, I32);
    getter!(as_f32, f32, F32);
    getter!(as_f64, f64, F64);

    /// Wrap an owned buffer without copying (hot-path constructor: the host
    /// fused engine and the coordinator's batch stacker build their output
    /// in place and hand the allocation over).
    pub fn from_data(data: TensorData, shape: &[usize]) -> Tensor {
        let len = match &data {
            TensorData::U8(v) => v.len(),
            TensorData::U16(v) => v.len(),
            TensorData::I32(v) => v.len(),
            TensorData::F32(v) => v.len(),
            TensorData::F64(v) => v.len(),
        };
        assert_eq!(
            len,
            shape.iter().product::<usize>(),
            "data length does not match shape {:?}",
            shape
        );
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn zeros(dt: DType, shape: &[usize]) -> Tensor {
        let n = shape.iter().product();
        let data = match dt {
            DType::U8 => TensorData::U8(vec![0; n]),
            DType::U16 => TensorData::U16(vec![0; n]),
            DType::I32 => TensorData::I32(vec![0; n]),
            DType::F32 => TensorData::F32(vec![0.0; n]),
            DType::F64 => TensorData::F64(vec![0.0; n]),
        };
        Tensor { shape: shape.to_vec(), data }
    }

    /// Build from f64 values with the write-boundary semantics of `dt`
    /// (round + saturate for integer image types).
    pub fn from_f64_cast(values: &[f64], shape: &[usize], dt: DType) -> Tensor {
        assert_eq!(values.len(), shape.iter().product::<usize>());
        let data = match dt {
            DType::U8 => TensorData::U8(values.iter().map(|&v| sat(v, 255.0) as u8).collect()),
            DType::U16 => {
                TensorData::U16(values.iter().map(|&v| sat(v, 65535.0) as u16).collect())
            }
            DType::I32 => TensorData::I32(values.iter().map(|&v| v.round() as i32).collect()),
            DType::F32 => TensorData::F32(values.iter().map(|&v| v as f32).collect()),
            DType::F64 => TensorData::F64(values.to_vec()),
        };
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn dtype(&self) -> DType {
        match &self.data {
            TensorData::U8(_) => DType::U8,
            TensorData::U16(_) => DType::U16,
            TensorData::I32(_) => DType::I32,
            TensorData::F32(_) => DType::F32,
            TensorData::F64(_) => DType::F64,
        }
    }

    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn size_bytes(&self) -> usize {
        self.len() * self.dtype().size_bytes()
    }

    pub fn data(&self) -> &TensorData {
        &self.data
    }

    /// Raw bytes of the storage (row-major), for literal construction.
    pub fn raw_bytes(&self) -> &[u8] {
        match &self.data {
            TensorData::U8(v) => v.as_slice(),
            TensorData::U16(v) => bytemuck_cast(v),
            TensorData::I32(v) => bytemuck_cast(v),
            TensorData::F32(v) => bytemuck_cast(v),
            TensorData::F64(v) => bytemuck_cast(v),
        }
    }

    /// Lossless widening to f64 (for oracles and assertions).
    pub fn to_f64_vec(&self) -> Vec<f64> {
        match &self.data {
            TensorData::U8(v) => v.iter().map(|&x| x as f64).collect(),
            TensorData::U16(v) => v.iter().map(|&x| x as f64).collect(),
            TensorData::I32(v) => v.iter().map(|&x| x as f64).collect(),
            TensorData::F32(v) => v.iter().map(|&x| x as f64).collect(),
            TensorData::F64(v) => v.clone(),
        }
    }

    /// Cast with write-boundary semantics (round + saturate to int types).
    pub fn cast(&self, dt: DType) -> Tensor {
        if dt == self.dtype() {
            return self.clone();
        }
        Tensor::from_f64_cast(&self.to_f64_vec(), &self.shape, dt)
    }

    /// Same data viewed under a new shape (element count must match).
    pub fn reshaped(&self, shape: &[usize]) -> Tensor {
        assert_eq!(self.len(), shape.iter().product::<usize>(), "reshape element mismatch");
        Tensor { shape: shape.to_vec(), data: self.data.clone() }
    }
}

fn sat(v: f64, hi: f64) -> f64 {
    v.round().clamp(0.0, hi)
}

fn bytemuck_cast<T>(v: &[T]) -> &[u8] {
    // SAFETY: the only callers are `raw_bytes`'s match arms, which pass
    // `&[u16]`/`&[i32]`/`&[f32]`/`&[f64]` — plain-old-data numeric types
    // with no padding, niches or invalid bit patterns, so every byte of the
    // slice is initialized and any byte sequence is a valid `u8`. The cast
    // only DECREASES the alignment requirement (`u8` has alignment 1, and
    // `v.as_ptr()` is non-null and well-aligned even for an empty slice, as
    // Vec guarantees a dangling-but-aligned pointer). The length is
    // `size_of_val(v)` = `v.len() * size_of::<T>()`, exactly the extent of
    // the allocation being viewed, and the returned borrow keeps `v`'s
    // lifetime, so the bytes cannot outlive or alias a mutation of the
    // storage. This argument is machine-checked: CI runs the `tensor::`
    // unit tests (including `raw_bytes_*` below) under Miri.
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, std::mem::size_of_val(v)) }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn raw_bytes_views_every_dtype_with_exact_lengths() {
        let cases = [
            (Tensor::from_u8(&[1, 2, 3, 4, 5, 6], &[2, 3]), DType::U8),
            (Tensor::from_u16(&[1, 513, 65535, 0], &[4]), DType::U16),
            (Tensor::from_i32(&[-1, 7, i32::MIN, i32::MAX], &[2, 2]), DType::I32),
            (Tensor::from_f32(&[0.5, -2.0, f32::NAN], &[3]), DType::F32),
            (Tensor::from_f64(&[0.25, -8.0], &[2]), DType::F64),
        ];
        for (t, dt) in &cases {
            let bytes = t.raw_bytes();
            assert_eq!(bytes.len(), t.len() * dt.size_bytes(), "{dt}: byte length");
            assert_eq!(bytes.len(), t.size_bytes(), "{dt}: size_bytes agrees");
        }
    }

    #[test]
    fn raw_bytes_are_the_native_endian_storage_bytes() {
        // spot-check the layout the XLA literal boundary relies on: the
        // bytes are the elements' native (little-endian on CI) encodings,
        // in row-major element order
        let t = Tensor::from_u16(&[0x0102, 0x0304], &[2]);
        let mut want = Vec::new();
        want.extend_from_slice(&0x0102u16.to_ne_bytes());
        want.extend_from_slice(&0x0304u16.to_ne_bytes());
        assert_eq!(t.raw_bytes(), &want[..]);
        let t = Tensor::from_i32(&[-2], &[1]);
        assert_eq!(t.raw_bytes(), (-2i32).to_ne_bytes());
        let t = Tensor::from_f64(&[1.5], &[1]);
        assert_eq!(t.raw_bytes(), 1.5f64.to_ne_bytes());
    }

    #[test]
    fn raw_bytes_of_empty_tensors_are_empty_not_ub() {
        // the dangling-but-aligned Vec pointer case the SAFETY comment
        // leans on — Miri verifies from_raw_parts is sound here too
        for dt in [DType::U8, DType::U16, DType::I32, DType::F32, DType::F64] {
            let t = Tensor::zeros(dt, &[0]);
            assert!(t.raw_bytes().is_empty(), "{dt}");
            assert!(t.is_empty(), "{dt}");
        }
    }
}
