//! Image helpers for the cv/npp wrappers and the preprocessing pipeline.

use super::{DType, Tensor};

/// Packed (HWC) vs planar (CHW) pixel layout — the paper's Split WOp
/// transforms packed to planar (Fig. 11).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ImageLayout {
    Packed,
    Planar,
}

/// A crop rectangle in frame coordinates: x0, y0, width, height.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Rect {
    pub x0: i32,
    pub y0: i32,
    pub w: i32,
    pub h: i32,
}

impl Rect {
    pub fn new(x0: i32, y0: i32, w: i32, h: i32) -> Rect {
        Rect { x0, y0, w, h }
    }

    /// Flatten a batch of rects into the i32[B, 4] tensor the preproc
    /// artifact expects.
    pub fn batch_tensor(rects: &[Rect]) -> Tensor {
        let mut v = Vec::with_capacity(rects.len() * 4);
        for r in rects {
            v.extend_from_slice(&[r.x0, r.y0, r.w, r.h]);
        }
        Tensor::from_i32(&v, &[rects.len(), 4])
    }

    pub fn contains_within(&self, fw: i32, fh: i32) -> bool {
        self.x0 >= 0 && self.y0 >= 0 && self.w > 0 && self.h > 0
            && self.x0 + self.w <= fw
            && self.y0 + self.h <= fh
    }
}

/// Deterministic synthetic video frame (u8 HWC), used by examples and
/// experiments in place of the paper's broadcast footage.
pub fn make_frame(h: usize, w: usize, seed: u64) -> Tensor {
    let mut data = Vec::with_capacity(h * w * 3);
    let mut s = seed.wrapping_mul(0x9E3779B97F4A7C15) | 1;
    for y in 0..h {
        for x in 0..w {
            // smooth gradients + hash noise: looks like real footage to the
            // memory system (incompressible, spatially varying)
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            let n = (s & 0x3F) as usize;
            data.push((((x * 255) / w + n) % 256) as u8);
            data.push((((y * 255) / h + n) % 256) as u8);
            data.push((((x + y) * 255 / (w + h) + n) % 256) as u8);
        }
    }
    Tensor::from_u8(&data, &[h, w, 3])
}

/// CPU reference crop (u8 packed frame -> u8 packed crop), used by hostref.
pub fn crop_frame(frame: &Tensor, r: Rect) -> Tensor {
    let (fh, fw) = (frame.shape()[0], frame.shape()[1]);
    assert_eq!(frame.dtype(), DType::U8);
    assert!(r.contains_within(fw as i32, fh as i32), "rect {r:?} outside {fw}x{fh}");
    let src = frame.as_u8().unwrap();
    let (h, w) = (r.h as usize, r.w as usize);
    let mut out = Vec::with_capacity(h * w * 3);
    for y in 0..h {
        let row = ((r.y0 as usize + y) * fw + r.x0 as usize) * 3;
        out.extend_from_slice(&src[row..row + w * 3]);
    }
    Tensor::from_u8(&out, &[h, w, 3])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rect_batch_tensor_layout() {
        let t = Rect::batch_tensor(&[Rect::new(1, 2, 3, 4), Rect::new(5, 6, 7, 8)]);
        assert_eq!(t.shape(), &[2, 4]);
        assert_eq!(t.as_i32().unwrap(), &[1, 2, 3, 4, 5, 6, 7, 8]);
    }

    #[test]
    fn frame_is_deterministic() {
        let a = make_frame(16, 16, 7);
        let b = make_frame(16, 16, 7);
        assert_eq!(a, b);
        let c = make_frame(16, 16, 8);
        assert_ne!(a, c);
    }

    #[test]
    fn crop_extracts_roi() {
        let f = make_frame(32, 32, 1);
        let c = crop_frame(&f, Rect::new(4, 8, 10, 6));
        assert_eq!(c.shape(), &[6, 10, 3]);
        let fsrc = f.as_u8().unwrap();
        let csrc = c.as_u8().unwrap();
        // spot-check corner pixel
        assert_eq!(csrc[0], fsrc[(8 * 32 + 4) * 3]);
    }

    #[test]
    fn rect_bounds_check() {
        assert!(Rect::new(0, 0, 10, 10).contains_within(10, 10));
        assert!(!Rect::new(1, 0, 10, 10).contains_within(10, 10));
        assert!(!Rect::new(0, 0, 0, 10).contains_within(10, 10));
    }
}
