//! Host-side tensor types: dtypes, shapes and typed storage.
//!
//! These are the Rust mirror of the paper's `Ptr<ND, T>` data structures
//! (§IV-B): they carry the shape information the executor uses to infer grid
//! dimensions / pick batched artifacts, and they marshal to/from XLA literals.

mod dtype;
mod image;
mod tensor_impl;

pub use dtype::DType;
pub use image::{crop_frame, make_frame, ImageLayout, Rect};
pub use tensor_impl::{Tensor, TensorData};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_sizes() {
        assert_eq!(DType::U8.size_bytes(), 1);
        assert_eq!(DType::U16.size_bytes(), 2);
        assert_eq!(DType::I32.size_bytes(), 4);
        assert_eq!(DType::F32.size_bytes(), 4);
        assert_eq!(DType::F64.size_bytes(), 8);
    }

    #[test]
    fn dtype_names_roundtrip() {
        for dt in [DType::U8, DType::U16, DType::I32, DType::F32, DType::F64] {
            assert_eq!(DType::parse(dt.name()).unwrap(), dt);
        }
        assert!(DType::parse("q4").is_none());
    }

    #[test]
    fn tensor_f32_roundtrip() {
        let t = Tensor::from_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]);
        assert_eq!(t.shape(), &[2, 2]);
        assert_eq!(t.len(), 4);
        assert_eq!(t.dtype(), DType::F32);
        assert_eq!(t.as_f32().unwrap(), &[1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn tensor_cast_saturates() {
        let t = Tensor::from_f32(&[-5.0, 0.4, 254.6, 300.0], &[4]);
        let u = t.cast(DType::U8);
        assert_eq!(u.as_u8().unwrap(), &[0, 0, 255, 255]);
    }

    #[test]
    fn tensor_to_f64_vec_from_all_dtypes() {
        let t = Tensor::from_u8(&[0, 128, 255], &[3]);
        assert_eq!(t.to_f64_vec(), vec![0.0, 128.0, 255.0]);
        let t = Tensor::from_i32(&[-1, 2], &[2]);
        assert_eq!(t.to_f64_vec(), vec![-1.0, 2.0]);
    }

    #[test]
    #[should_panic]
    fn tensor_shape_mismatch_panics() {
        Tensor::from_f32(&[1.0, 2.0], &[3]);
    }

    #[test]
    fn size_bytes_accounting() {
        let t = Tensor::zeros(DType::F32, &[10, 20]);
        assert_eq!(t.size_bytes(), 800);
    }
}
