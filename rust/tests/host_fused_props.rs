//! Property tests for the host fused engine and the batch packers — pure
//! host code (no XLA), so thousands of random cases run everywhere.
//!
//! Numerics contract being enforced:
//! * every f64-accumulated path (all integer outputs, f64 anywhere, i32
//!   input) is BIT-equal to `hostref::run_pipeline`;
//! * the f32 fast path (u8/u16/f32 -> f32 chains) stays within the same
//!   epsilon the engine equivalence suite grants the interpreter tier (1e-3);
//! * `slice_batch`/`concat_batch`/`stack_batch` are lossless for all five
//!   dtypes, and HF-stacking never changes per-item results.

use fkl::exec::{concat_batch, slice_batch, stack_batch, Engine, HostFusedEngine};
use fkl::hostref;
use fkl::ops::{Opcode, Pipeline, ALL_OPCODES};
use fkl::proplite::{forall, Rng};
use fkl::tensor::{DType, Tensor};

const DTYPES: [DType; 5] = [DType::U8, DType::U16, DType::I32, DType::F32, DType::F64];

fn rand_tensor(rng: &mut Rng, shape: &[usize], dt: DType) -> Tensor {
    let n: usize = shape.iter().product();
    match dt {
        DType::U8 => Tensor::from_u8(&rng.vec_u8(n), shape),
        DType::U16 => {
            let v: Vec<u16> = (0..n).map(|_| (rng.next_u64() & 0xFFF) as u16).collect();
            Tensor::from_u16(&v, shape)
        }
        DType::I32 => {
            let v: Vec<i32> =
                (0..n).map(|_| (rng.next_u64() & 0xFFFF) as i32 - 0x8000).collect();
            Tensor::from_i32(&v, shape)
        }
        DType::F32 => Tensor::from_f32(&rng.vec_f32(n, -4.0, 4.0), shape),
        DType::F64 => {
            let v: Vec<f64> = (0..n).map(|_| rng.f64(-4.0, 4.0)).collect();
            Tensor::from_f64(&v, shape)
        }
    }
}

fn rand_chain(rng: &mut Rng, ops: &[Opcode], k: usize) -> Vec<(Opcode, f64)> {
    (0..k)
        .map(|_| {
            let op = *rng.pick(ops);
            let param = match op {
                // keep divisors away from zero so relative error stays tame
                Opcode::Div => {
                    let sign = if rng.bool() { 1.0 } else { -1.0 };
                    sign * rng.f64(0.8, 1.25)
                }
                _ => rng.f64(-4.0, 4.0),
            };
            (op, param)
        })
        .collect()
}

#[test]
fn prop_slice_concat_roundtrip_all_dtypes() {
    forall(250, |rng| {
        let dt = *rng.pick(&DTYPES);
        let b = rng.usize(1, 7);
        let shape = vec![rng.usize(1, 9), rng.usize(1, 9)];
        let mut full = vec![b];
        full.extend_from_slice(&shape);
        let t = rand_tensor(rng, &full, dt);
        let item_elems: usize = shape.iter().product();
        let parts: Vec<Tensor> =
            (0..b).map(|i| slice_batch(&t, i, item_elems, &shape)).collect();
        for p in &parts {
            assert_eq!(p.shape()[0], 1);
            assert_eq!(p.dtype(), dt);
        }
        let back = concat_batch(&parts, &shape);
        assert_eq!(back, t, "{dt} b={b} slice->concat must be lossless");
    });
}

#[test]
fn prop_stack_batch_equals_concat_with_pad_replication() {
    forall(250, |rng| {
        let dt = *rng.pick(&DTYPES);
        let m = rng.usize(1, 6);
        let bucket = m + rng.usize(0, 4);
        let shape = vec![rng.usize(1, 6), rng.usize(1, 6)];
        let mut item_shape = vec![1];
        item_shape.extend_from_slice(&shape);
        let items: Vec<Tensor> = (0..m).map(|_| rand_tensor(rng, &item_shape, dt)).collect();
        let refs: Vec<&Tensor> = items.iter().collect();
        let stacked = stack_batch(&refs, bucket, &shape);

        // reference semantics: clone parts, pad with the last, concat
        let mut parts: Vec<Tensor> = items.clone();
        for _ in m..bucket {
            parts.push(items[m - 1].clone());
        }
        let want = concat_batch(&parts, &shape);
        assert_eq!(stacked, want, "{dt} m={m} bucket={bucket}");
    });
}

#[test]
fn prop_f64_accum_paths_bit_exact_vs_oracle() {
    // every dtype pair except the dedicated f32 fast path accumulates in f64
    // and must reproduce the oracle EXACTLY — all opcodes, params, batches
    forall(300, |rng| {
        // built per case: the engine's interior mutability (plan cache) is
        // not RefUnwindSafe, so it cannot be captured across catch_unwind
        let eng = HostFusedEngine::new();
        let dtin = *rng.pick(&DTYPES);
        let dtout = loop {
            let dt = *rng.pick(&DTYPES);
            let f32_fastpath =
                dt == DType::F32 && matches!(dtin, DType::U8 | DType::U16 | DType::F32);
            if !f32_fastpath {
                break dt;
            }
        };
        let k = rng.usize(1, 13);
        let chain = rand_chain(rng, &ALL_OPCODES, k);
        let batch = rng.usize(1, 5);
        let shape = vec![rng.usize(1, 8), rng.usize(1, 8)];
        let p = Pipeline::from_opcodes(&chain, &shape, batch, dtin, dtout).unwrap();
        let mut full = vec![batch];
        full.extend_from_slice(&shape);
        let x = rand_tensor(rng, &full, dtin);
        let got = eng.run(&p, &x).unwrap();
        let want = hostref::run_pipeline(&p, &x);
        assert_eq!(got, want, "{dtin}->{dtout} chain {chain:?}");
    });
}

#[test]
fn prop_f32_fastpath_within_engine_epsilon() {
    // u8/u16/f32 -> f32 chains run in f32 registers; they must stay within
    // the 1e-3 relative epsilon the engine equivalence suite uses. Exp and
    // Sqrt are excluded: Exp can overflow f32 where f64 stays finite, and
    // Sqrt turns cancellation-level absolute error into sqrt-scale error —
    // pipelines needing exactness get it from the f64 paths above.
    let ops = [
        Opcode::Nop,
        Opcode::Add,
        Opcode::Sub,
        Opcode::Mul,
        Opcode::Div,
        Opcode::Abs,
        Opcode::Neg,
        Opcode::Min,
        Opcode::Max,
        Opcode::Log,
        Opcode::Clamp01,
    ];
    forall(300, |rng| {
        let eng = HostFusedEngine::new();
        let dtin = *rng.pick(&[DType::U8, DType::U16, DType::F32]);
        let k = rng.usize(1, 13);
        let chain = rand_chain(rng, &ops, k);
        let batch = rng.usize(1, 5);
        let shape = vec![rng.usize(1, 8), rng.usize(1, 8)];
        let p = Pipeline::from_opcodes(&chain, &shape, batch, dtin, DType::F32).unwrap();
        let mut full = vec![batch];
        full.extend_from_slice(&shape);
        let x = rand_tensor(rng, &full, dtin);
        let got = eng.run(&p, &x).unwrap();
        let want = hostref::run_pipeline(&p, &x);
        assert_eq!(got.shape(), want.shape());
        for (i, (a, b)) in got.to_f64_vec().iter().zip(want.to_f64_vec()).enumerate() {
            assert!(
                (a - b).abs() <= 1e-3 + 1e-3 * b.abs(),
                "{dtin}->f32 elem {i}: {a} vs {b} (chain {chain:?})"
            );
        }
    });
}

#[test]
fn prop_hf_stacking_never_changes_per_item_results() {
    // running m items as one stacked batch then slicing must equal running
    // each item alone — the invariant the coordinator's HF path rests on
    forall(150, |rng| {
        let eng = HostFusedEngine::new();
        let dtin = *rng.pick(&DTYPES);
        let dtout = *rng.pick(&DTYPES);
        let k = rng.usize(1, 8);
        let chain = rand_chain(rng, &ALL_OPCODES, k);
        let m = rng.usize(1, 5);
        let shape = vec![rng.usize(1, 7), rng.usize(1, 7)];
        let mut item_shape = vec![1];
        item_shape.extend_from_slice(&shape);
        let items: Vec<Tensor> = (0..m).map(|_| rand_tensor(rng, &item_shape, dtin)).collect();

        let p1 = Pipeline::from_opcodes(&chain, &shape, 1, dtin, dtout).unwrap();
        let pm = Pipeline::from_opcodes(&chain, &shape, m, dtin, dtout).unwrap();
        let refs: Vec<&Tensor> = items.iter().collect();
        let stacked_out = eng.run(&pm, &stack_batch(&refs, m, &shape)).unwrap();
        let item_elems: usize = shape.iter().product();
        for (i, item) in items.iter().enumerate() {
            let alone = eng.run(&p1, item).unwrap();
            let sliced = slice_batch(&stacked_out, i, item_elems, &shape);
            assert_eq!(alone, sliced, "item {i} of {m}, {dtin}->{dtout}");
        }
    });
}
