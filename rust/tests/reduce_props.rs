//! Property tests for the fused reduction tier: determinism and
//! oracle-equality of fold-while-reading, randomized over dtypes, kinds,
//! axes, shapes and thread counts — pure host code, runs everywhere.
//!
//! Contract being enforced (the reduction half of the numerics story):
//! * every reduce pass accumulates in f64 per fixed-size block and combines
//!   partials in a fixed tree order, so results are BIT-equal to the
//!   materializing `hostref::run_pipeline` oracle on all 5 dtypes;
//! * the thread count (1/2/8) never changes a single bit — chunking is a
//!   property of the data, not the scheduler;
//! * empty and 1-element reductions finalize to the documented identities;
//! * NaN-bearing `Min`/`Max` inputs reduce to the extremum of the finite
//!   values (IEEE minNum/maxNum fold), and all-NaN inputs finalize to the
//!   fold identity — deterministically.

use fkl::chain::{Chain, ComputeOp};
use fkl::exec::{Engine, HostFusedEngine};
use fkl::hostref;
use fkl::ops::{Opcode, Pipeline, ReduceAxis, ReduceKind, ReduceSpec, ALL_REDUCE_KINDS};
use fkl::proplite::{forall, Rng};
use fkl::tensor::{DType, Tensor};

const DTYPES: [DType; 5] = [DType::U8, DType::U16, DType::I32, DType::F32, DType::F64];

/// Bit-exact tensor comparison that treats equal NaN bit patterns as equal
/// (plain `==` on f64 tensors fails on NaN statistics like the empty Mean).
fn assert_bits_eq(got: &Tensor, want: &Tensor, ctx: &str) {
    assert_eq!(got.shape(), want.shape(), "{ctx}: shape");
    assert_eq!(got.dtype(), want.dtype(), "{ctx}: dtype");
    for (i, (a, b)) in got.to_f64_vec().iter().zip(want.to_f64_vec()).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{ctx}: lane {i}: {a} vs {b}");
    }
}

/// Deterministic random tensor in a range where every chain stays finite.
fn rand_tensor(rng: &mut Rng, shape: &[usize], dt: DType) -> Tensor {
    let n: usize = shape.iter().product();
    let vals: Vec<f64> = (0..n).map(|_| rng.f64(0.0, 200.0)).collect();
    Tensor::from_f64_cast(&vals, shape, dt)
}

fn reduce_pipe(
    body: &[(Opcode, f64)],
    shape: &[usize],
    batch: usize,
    dtin: DType,
    spec: ReduceSpec,
) -> Pipeline {
    let stages: Vec<ComputeOp> = body.iter().map(|&(op, p)| ComputeOp::scalar(op, p)).collect();
    fkl::chain::build_erased_reduce(&stages, shape, batch, dtin, spec)
}

#[test]
fn prop_reduce_is_bit_equal_to_the_oracle_across_dtypes_and_threads() {
    forall(60, |rng| {
        let dt = *rng.pick(&DTYPES);
        let kind = *rng.pick(&ALL_REDUCE_KINDS);
        let axis = if rng.bool() { ReduceAxis::Full } else { ReduceAxis::PerChannel };
        // sizes that cross REDUCE_BLOCK boundaries sometimes (3072 elems)
        let n = rng.usize(1, 5000);
        let batch = rng.usize(1, 4);
        let mut full = vec![batch];
        full.push(n);
        let x = rand_tensor(rng, &full, dt);
        let p = reduce_pipe(
            &[(Opcode::Mul, 0.5), (Opcode::Add, 1.0)],
            &[n],
            batch,
            dt,
            ReduceSpec::single(kind, axis),
        );
        let want = hostref::run_pipeline(&p, &x);
        for threads in [1usize, 2, 8] {
            let eng = HostFusedEngine::with_threads(threads);
            let got = eng.run(&p, &x).unwrap();
            assert_bits_eq(&got, &want, &format!("{dt} {kind:?} {axis:?} n={n} t{threads}"));
        }
    });
}

#[test]
fn prop_pair_reductions_match_their_singles() {
    forall(40, |rng| {
        let dt = *rng.pick(&DTYPES);
        let a = *rng.pick(&ALL_REDUCE_KINDS);
        let b = *rng.pick(&ALL_REDUCE_KINDS);
        let axis = if rng.bool() { ReduceAxis::Full } else { ReduceAxis::PerChannel };
        let n = rng.usize(1, 4000);
        let x = rand_tensor(rng, &[1, n], dt);
        let eng = HostFusedEngine::with_threads(rng.usize(1, 4));
        let pair = eng
            .run(&reduce_pipe(&[], &[n], 1, dt, ReduceSpec::pair(a, b, axis)), &x)
            .unwrap();
        let lone_a = eng
            .run(&reduce_pipe(&[], &[n], 1, dt, ReduceSpec::single(a, axis)), &x)
            .unwrap();
        let lone_b = eng
            .run(&reduce_pipe(&[], &[n], 1, dt, ReduceSpec::single(b, axis)), &x)
            .unwrap();
        let lanes = lone_a.len();
        let (pv, av, bv) = (pair.to_f64_vec(), lone_a.to_f64_vec(), lone_b.to_f64_vec());
        for lane in 0..lanes {
            assert_eq!(pv[lane].to_bits(), av[lane].to_bits(), "{a:?} lane {lane}");
            assert_eq!(pv[lanes + lane].to_bits(), bv[lane].to_bits(), "{b:?} lane {lane}");
        }
    });
}

#[test]
fn prop_block_boundaries_are_exact() {
    // n pinned around the block size: partial-block tails and multi-block
    // trees must agree with the oracle bitwise at every boundary shape
    let block = 3072usize; // ops::kernel::REDUCE_BLOCK
    let mut rng = Rng::new(99);
    for n in [1, 2, 3, block - 1, block, block + 1, 2 * block, 2 * block + 5, 3 * block + 1] {
        let x = rand_tensor(&mut rng, &[1, n], DType::F64);
        for axis in [ReduceAxis::Full, ReduceAxis::PerChannel] {
            let spec = ReduceSpec::single(ReduceKind::Sum, axis);
            let p = reduce_pipe(&[], &[n], 1, DType::F64, spec);
            let want = hostref::run_pipeline(&p, &x);
            for threads in [1usize, 2, 8] {
                let got = HostFusedEngine::with_threads(threads).run(&p, &x).unwrap();
                assert_bits_eq(&got, &want, &format!("n={n} {axis:?} t{threads}"));
            }
        }
    }
}

#[test]
fn empty_and_single_element_reductions() {
    let eng = HostFusedEngine::with_threads(2);
    for kind in ALL_REDUCE_KINDS {
        // empty: the fold identity (Mean of nothing is NaN — loudly)
        let p = reduce_pipe(&[], &[0], 1, DType::F32, ReduceSpec::single(kind, ReduceAxis::Full));
        let empty = Tensor::zeros(DType::F32, &[1, 0]);
        let got = eng.run(&p, &empty).unwrap();
        assert_bits_eq(&got, &hostref::run_pipeline(&p, &empty), &format!("empty {kind:?}"));
        let v = got.as_f64().unwrap()[0];
        match kind {
            ReduceKind::Sum | ReduceKind::SumSq => assert_eq!(v, 0.0),
            ReduceKind::Min => assert_eq!(v, f64::INFINITY),
            ReduceKind::Max => assert_eq!(v, f64::NEG_INFINITY),
            ReduceKind::Mean => assert!(v.is_nan()),
        }

        // 1 element: every statistic of [x] is x (or x² for SumSq)
        let p1 = reduce_pipe(&[], &[1], 1, DType::F32, ReduceSpec::single(kind, ReduceAxis::Full));
        let one = Tensor::from_f32(&[3.0], &[1, 1]);
        let got = eng.run(&p1, &one).unwrap();
        let want = if kind == ReduceKind::SumSq { 9.0 } else { 3.0 };
        assert_eq!(got.as_f64().unwrap(), &[want], "{kind:?}");
    }
}

#[test]
fn nan_bearing_min_max_skip_nans_deterministically() {
    let eng1 = HostFusedEngine::with_threads(1);
    let eng8 = HostFusedEngine::with_threads(8);
    // NaNs scattered among finite values: the fold skips them (IEEE
    // minNum/maxNum), so the extremum of the finite values wins
    let vals = [f32::NAN, 2.0, -7.5, f32::NAN, 11.25, 0.0, f32::NAN, -1.0];
    let x = Tensor::from_f32(&vals, &[1, 8]);
    for (kind, want) in [(ReduceKind::Max, 11.25), (ReduceKind::Min, -7.5)] {
        let p = reduce_pipe(&[], &[8], 1, DType::F32, ReduceSpec::single(kind, ReduceAxis::Full));
        let got = eng1.run(&p, &x).unwrap();
        assert_eq!(got.as_f64().unwrap(), &[want], "{kind:?}");
        assert_bits_eq(&got, &eng8.run(&p, &x).unwrap(), &format!("{kind:?} threads"));
        assert_bits_eq(&got, &hostref::run_pipeline(&p, &x), &format!("{kind:?} oracle"));
    }
    // ... while Sum/Mean propagate NaN (and still agree with the oracle)
    let sum_spec = ReduceSpec::single(ReduceKind::Sum, ReduceAxis::Full);
    let p = reduce_pipe(&[], &[8], 1, DType::F32, sum_spec);
    let got = eng1.run(&p, &x).unwrap();
    assert!(got.as_f64().unwrap()[0].is_nan());
    assert_bits_eq(&got, &hostref::run_pipeline(&p, &x), "sum nan");

    // all-NaN Max finalizes to the fold identity, bit-for-bit
    let all_nan = Tensor::from_f32(&[f32::NAN; 4], &[1, 4]);
    let max_spec = ReduceSpec::single(ReduceKind::Max, ReduceAxis::Full);
    let p = reduce_pipe(&[], &[4], 1, DType::F32, max_spec);
    let got = eng1.run(&p, &all_nan).unwrap();
    assert_eq!(got.as_f64().unwrap(), &[f64::NEG_INFINITY]);
    assert_bits_eq(&got, &hostref::run_pipeline(&p, &all_nan), "all-nan max");
}

#[test]
fn prop_lane_structured_bodies_compose_with_per_channel_stats() {
    // cvtcolor + per-channel math BEFORE a per-channel reduction: the lane
    // rule (global index % 3) is shared between body and statistics
    forall(40, |rng| {
        let h = rng.usize(1, 12);
        let w = rng.usize(1, 12);
        let batch = rng.usize(1, 3);
        let x = rand_tensor(rng, &[batch, h, w, 3], DType::U8);
        let typed = Chain::read::<fkl::chain::U8>(&[h, w, 3])
            .batch(batch)
            .map(fkl::chain::CvtColor)
            .map(fkl::chain::MulC3([0.5, 0.25, 2.0]))
            .reduce_pair_per_channel(ReduceKind::Mean, ReduceKind::SumSq);
        let p = typed.pipeline();
        let want = hostref::run_pipeline(p, &x);
        for threads in [1usize, 2, 8] {
            let eng = HostFusedEngine::with_threads(threads);
            assert_bits_eq(
                &eng.run(p, &x).unwrap(),
                &want,
                &format!("{h}x{w} b{batch} t{threads}"),
            );
        }
    });
}
