//! `fkl serve --trace-out / --metrics-json` and `fkl metrics --demo`
//! export contracts, exercised against the real binary: the capture must
//! parse back through the in-crate JSON parser as Chrome trace events, and
//! the metrics dump must carry the snapshot's counters.

use std::process::{Command, Output};

use fkl::jsonlite::parse;

fn fkl(args: &[&str]) -> Output {
    Command::new(env!("CARGO_BIN_EXE_fkl")).args(args).output().expect("spawn fkl")
}

fn tmp(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("fkl-{}-{name}", std::process::id()))
}

#[test]
fn serve_writes_a_perfetto_openable_trace_and_a_metrics_dump() {
    let trace_path = tmp("trace.json");
    let metrics_path = tmp("metrics.json");
    let out = fkl(&[
        "serve",
        "--requests",
        "40",
        "--batch-window-us",
        "200",
        "--trace-out",
        trace_path.to_str().unwrap(),
        "--metrics-json",
        metrics_path.to_str().unwrap(),
    ]);
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert_eq!(out.status.code(), Some(0), "serve must exit clean: {stdout}");
    assert!(stdout.contains("trace capture:"), "capture announced: {stdout}");
    assert!(stdout.contains("metrics dump:"), "dump announced: {stdout}");
    assert!(stdout.contains("fusion_efficiency="), "efficiency on the console: {stdout}");

    // the capture is valid Chrome trace-event JSON (ph/ts/dur/pid/tid)
    let trace_src = std::fs::read_to_string(&trace_path).expect("trace written");
    let trace = parse(&trace_src).expect("trace parses");
    let events = trace["traceEvents"].as_arr().expect("traceEvents array");
    assert!(events.len() >= 40, "every request traces spans: {} events", events.len());
    for e in events {
        assert_eq!(e["ph"].as_str(), Some("X"), "complete events: {}", e.to_json());
        for key in ["ts", "dur", "pid", "tid"] {
            assert!(e[key].as_f64().is_some(), "missing {key}: {}", e.to_json());
        }
        assert!(e["name"].as_str().is_some(), "named event: {}", e.to_json());
    }
    assert!(
        events.iter().any(|e| e["name"].as_str() == Some("launch")),
        "the window launched fused work"
    );

    // the dump carries the snapshot's counters, machine-readably
    let dump_src = std::fs::read_to_string(&metrics_path).expect("metrics written");
    let dump = parse(&dump_src).expect("metrics dump parses");
    assert_eq!(dump["completed"].as_f64(), Some(40.0), "all requests completed: {dump_src}");
    assert!(dump["launches"].as_f64().unwrap() >= 1.0);
    assert!(dump["bytes_read"].as_f64().unwrap() > 0.0, "byte accounting engaged");
    assert!(dump["fusion_efficiency"].as_f64().unwrap() > 1.0, "CMSD chain fuses");
    assert!(dump["tier_time_us"]["stacked"].as_f64().is_some());
    assert!(dump["latency_us"]["p999"].as_f64().is_some());
    assert!(dump["breakers"].as_arr().is_some(), "breaker list present");

    let _ = std::fs::remove_file(&trace_path);
    let _ = std::fs::remove_file(&metrics_path);
}

#[test]
fn metrics_demo_prints_the_snapshot_schema() {
    let out = fkl(&["metrics", "--demo"]);
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert_eq!(out.status.code(), Some(0), "metrics --demo must exit clean: {stdout}");
    let snap = parse(stdout.trim()).expect("demo output is one JSON object");
    assert!(snap["completed"].as_f64().unwrap() >= 1.0, "{stdout}");
    assert!(snap["fusion_efficiency"].as_f64().unwrap() > 1.0, "chain-5 traffic fuses");
    assert!(snap["tier_time_us"]["plan"].as_f64().is_some());
    assert!(snap["latency_us"]["count"].as_f64().unwrap() >= 1.0);
}

#[test]
fn metrics_without_demo_prints_usage() {
    let out = fkl(&["metrics"]);
    assert_eq!(out.status.code(), Some(0));
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("usage: fkl metrics --demo"), "{stderr}");
}
