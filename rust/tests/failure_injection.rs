//! Failure injection: the system must fail loudly and precisely, never
//! silently compute the wrong thing.

use fkl::coordinator::{BatchPolicy, EngineSelect, Service, ServiceConfig};
use fkl::ops::{Opcode, Pipeline};
use fkl::runtime::Registry;
use fkl::tensor::{DType, Tensor};

#[test]
fn missing_artifact_dir_is_a_clean_error() {
    let err = Registry::load("/nonexistent/artifacts").err().expect("must fail");
    let msg = format!("{err:#}");
    assert!(msg.contains("make artifacts"), "actionable message, got: {msg}");
}

#[test]
fn corrupt_manifest_is_rejected() {
    let dir = std::env::temp_dir().join("fkl_corrupt_manifest");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), "{ not json").unwrap();
    let err = Registry::load(&dir).err().expect("must fail");
    assert!(format!("{err:#}").contains("manifest"), "{err:#}");
}

#[test]
fn opcode_drift_is_detected_at_load() {
    // manifest whose opcode table disagrees with the Rust enum
    let dir = std::env::temp_dir().join("fkl_drift_manifest");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"version":1,"scale":"scaled","opcodes":{"nop":0,"add":9},"geometry":{},"artifacts":[]}"#,
    )
    .unwrap();
    let err = Registry::load(&dir).err().expect("must fail");
    assert!(format!("{err:#}").contains("opcode drift"), "{err:#}");
}

#[test]
#[cfg(feature = "pjrt")] // needs compiled artifacts + the PJRT runtime
fn wrong_input_arity_is_rejected() {
    let reg = std::rc::Rc::new(Registry::load(fkl::default_artifact_dir()).unwrap());
    let exec = fkl::runtime::Executor::new(reg);
    let x = Tensor::from_f32(&vec![0.0; 64], &[2, 4, 8]);
    let err = exec.run("chain_mul-add_f322f32_4x8_b2_pallas", &[&x]).unwrap_err();
    assert!(format!("{err:#}").contains("expected 2 inputs"), "{err:#}");
}

#[test]
#[cfg(feature = "pjrt")] // needs compiled artifacts + the PJRT runtime
fn uncovered_pipeline_reports_all_tiers_tried() {
    let ctx = fkl::cv::Context::with_select(fkl::exec::EngineSelect::Xla, None).unwrap();
    // exotic shape no artifact covers, even the interpreter
    let p = Pipeline::from_opcodes(
        &[(Opcode::Mul, 2.0)],
        &[7, 13],
        3,
        DType::F32,
        DType::F32,
    )
    .unwrap();
    let err = ctx.fused().unwrap().plan_for(&p).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("no artifact covers"), "{msg}");
}

#[test]
#[cfg(feature = "pjrt")] // needs compiled artifacts + the PJRT runtime
fn pipeline_dtype_mismatch_is_rejected_before_launch() {
    use fkl::exec::Engine;
    let ctx = fkl::cv::Context::with_select(fkl::exec::EngineSelect::Xla, None).unwrap();
    let p = Pipeline::from_opcodes(
        &[(Opcode::Nop, 0.0), (Opcode::Mul, 0.5), (Opcode::Sub, 3.0), (Opcode::Div, 1.7)],
        &[60, 120],
        50,
        DType::U8,
        DType::F32,
    )
    .unwrap();
    // f32 data fed to a u8 pipeline: the artifact input check must catch it
    let wrong = Tensor::from_f32(&vec![0.0; 50 * 7200], &[50, 60, 120]);
    let res = ctx.fused().unwrap().run(&p, &wrong);
    assert!(res.is_err(), "dtype mismatch must not silently launch");
}

#[test]
#[cfg(feature = "pjrt")] // needs compiled artifacts + the PJRT runtime
fn coordinator_survives_failing_requests() {
    use std::time::Duration;
    // a pipeline with no coverage: the service must reply with an error and
    // keep serving subsequent good requests (no poisoned worker)
    let svc = Service::start(ServiceConfig {
        artifact_dir: None,
        queue_cap: 64,
        policy: BatchPolicy { max_batch: 8, window: Duration::from_micros(100), ..Default::default() },
        engine: EngineSelect::Xla,
        ..ServiceConfig::default()
    });
    let bad = Pipeline::from_opcodes(&[(Opcode::Mul, 1.0)], &[7, 13], 1, DType::F32, DType::F32)
        .unwrap();
    let bad_rx = svc.submit(bad, Tensor::from_f32(&vec![0.0; 91], &[1, 7, 13])).unwrap();
    let bad_out = bad_rx.recv().unwrap();
    assert!(bad_out.is_err(), "uncovered pipeline must fail");

    let good = Pipeline::from_opcodes(
        &[(Opcode::Nop, 0.0), (Opcode::Mul, 0.5), (Opcode::Sub, 3.0), (Opcode::Div, 1.7)],
        &[60, 120],
        1,
        DType::U8,
        DType::F32,
    )
    .unwrap();
    let rx = svc.submit(good, Tensor::from_u8(&vec![9u8; 7200], &[1, 60, 120])).unwrap();
    assert!(rx.recv().unwrap().is_ok(), "service must keep working after a failure");
    let m = svc.metrics().unwrap();
    assert!(m.failed >= 1);
    svc.shutdown();
}

#[test]
fn coordinator_with_bad_artifact_dir_degrades_gracefully() {
    let svc = Service::start(ServiceConfig {
        artifact_dir: Some("/definitely/not/here".into()),
        queue_cap: 8,
        policy: BatchPolicy::default(),
        engine: EngineSelect::Xla,
        ..ServiceConfig::default()
    });
    let p = Pipeline::from_opcodes(&[(Opcode::Mul, 1.0)], &[4], 1, DType::F32, DType::F32)
        .unwrap();
    let rx = svc.submit(p, Tensor::from_f32(&[0.0; 4], &[1, 4])).unwrap();
    let out = rx.recv().unwrap();
    assert!(out.is_err());
    let err = out.unwrap_err();
    assert!(
        matches!(err, fkl::coordinator::ServeError::Unavailable(_)),
        "a service without a backend answers the typed Unavailable: {err}"
    );
    assert!(err.to_string().contains("registry"));
    svc.shutdown();
}

/// Minimal valid manifest (full opcode table for the drift check, zero
/// artifacts) so a `FusedEngine` can be built without `make artifacts`.
fn empty_registry() -> std::rc::Rc<Registry> {
    let dir = std::env::temp_dir().join("fkl_empty_manifest");
    std::fs::create_dir_all(&dir).unwrap();
    let opcodes: Vec<String> = fkl::ops::ALL_OPCODES
        .iter()
        .map(|o| format!("\"{}\":{}", o.name(), o.code()))
        .collect();
    let manifest = format!(
        "{{\"version\":1,\"scale\":\"scaled\",\"opcodes\":{{{}}},\"geometry\":{{}},\"artifacts\":[]}}",
        opcodes.join(",")
    );
    std::fs::write(dir.join("manifest.json"), manifest).unwrap();
    std::rc::Rc::new(Registry::load(&dir).unwrap())
}

#[test]
fn unsupported_body_is_typed_counted_and_served_by_the_host_loops() {
    use fkl::exec::{Engine, FusedEngine, UnsupportedOp};
    let eng = FusedEngine::new(empty_registry());

    // a lane-structured body — outside the XLA chain vocabulary; the fused
    // front door must detect it (typed + counted) and re-route to the host
    // single-pass engine, which runs it natively
    let p = fkl::chain::Chain::read::<fkl::chain::F32>(&[2, 3])
        .map(fkl::chain::CvtColor)
        .write()
        .into_pipeline();
    let x = Tensor::from_f32(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[1, 2, 3]);
    let out = eng.run(&p, &x).expect("host re-route serves the body");
    assert_eq!(out, fkl::hostref::run_pipeline(&p, &x), "served bit-exactly");
    let st = eng.planner_stats();
    assert_eq!(st.unsupported, 1, "the detection is counted for dashboards");
    assert_eq!(st.host, 1, "the serve lands in the host tier");
    assert!(!eng.last_was_fallback(), "host single-pass is fused, not per-op");
    assert_eq!(eng.last_launches(), 1);

    // the failure path stays typed: bad input -> error chain carries the
    // UnsupportedOp marker naming the offending op
    let wrong = Tensor::from_u8(&[0; 6], &[1, 2, 3]);
    let err = eng.run(&p, &wrong).unwrap_err();
    let typed =
        err.downcast_ref::<UnsupportedOp>().expect("typed UnsupportedOp in the error chain");
    assert_eq!(typed.engine, "fused");
    assert_eq!(typed.token, "cvtcolor");

    // the per-op engines reject the same body with the typed error directly
    let unfused = fkl::exec::UnfusedEngine::new(empty_registry());
    let err = unfused.run(&p, &x).unwrap_err();
    let typed = err.downcast_ref::<UnsupportedOp>().expect("typed in unfused too");
    assert_eq!(typed.engine, "unfused");
}

#[test]
fn structured_boundaries_still_refused_by_dense_only_engines() {
    use fkl::exec::{Engine, GraphEngine, UnfusedEngine, UnsupportedOp};
    use fkl::tensor::{make_frame, Rect};
    // a crop+resize read / split write chain: the DENSE-ONLY paths (per-op
    // artifact engines and the artifact planner) cannot reproduce its
    // access pattern and must refuse with typed errors — silently executing
    // as a dense chain would violate the layout contract
    let typed = fkl::chain::Chain::read_resize::<fkl::chain::U8>(Rect::new(0, 0, 16, 8), 8, 4)
        .map(fkl::chain::CvtColor)
        .cast::<fkl::chain::F32>()
        .write_split();
    let p = typed.pipeline().clone();
    let frame = make_frame(16, 16, 1);

    let unfused = UnfusedEngine::new(empty_registry());
    let err = unfused.run(&p, &frame).unwrap_err();
    let t = err.downcast_ref::<UnsupportedOp>().expect("typed refusal");
    assert_eq!(t.engine, "unfused");
    assert_eq!(t.token, "resize[8x4]");

    let graph = GraphEngine::new(empty_registry());
    let err = graph.run(&p, &frame).unwrap_err();
    assert_eq!(err.downcast_ref::<UnsupportedOp>().expect("typed refusal").engine, "graph");

    // the ARTIFACT planner refuses too: dense chain artifacts cannot serve
    // a structured boundary (it takes a dedicated family or the host tier)
    let err = fkl::fusion::plan_pipeline(&p, &empty_registry(), "pallas").unwrap_err();
    assert!(matches!(err, fkl::fusion::PlanError::StructuredBoundary(ref tok) if tok == "resize[8x4]"),
        "{err}");
}

#[test]
fn structured_boundaries_are_served_by_the_host_tier() {
    use fkl::exec::{Engine, FusedEngine, HostFusedEngine};
    use fkl::tensor::{make_frame, Rect};
    // ... while every path that can reach the host single-pass engine
    // SERVES the same pipeline: natively on the host backend, re-routed on
    // the fused front door — bit-equal to the structured oracle
    let typed = fkl::chain::Chain::read_resize::<fkl::chain::U8>(Rect::new(1, 2, 12, 6), 8, 4)
        .map(fkl::chain::CvtColor)
        .cast::<fkl::chain::F32>()
        .write_split();
    let p = typed.pipeline().clone();
    let frame = make_frame(20, 24, 3);
    let want = fkl::hostref::run_pipeline(&p, &frame);

    let host = HostFusedEngine::with_threads(1);
    let got = host.run(&p, &frame).expect("host engine serves structured pipelines");
    assert_eq!(got, want);
    assert_eq!(got.shape(), &[1, 3, 8, 4]);
    assert_eq!(typed.run_host(&host, &frame).expect("typed front door serves too"), want);
    assert_eq!(host.structured_runs(), 2);

    // the fused engine detects (typed, counted) and re-routes to its host
    // fallback instead of failing: structured chains are servable traffic
    let fused = FusedEngine::new(empty_registry());
    let got = fused.run(&p, &frame).expect("fused front door re-routes to the host tier");
    assert_eq!(got, want);
    let st = fused.planner_stats();
    assert_eq!(st.structured, 1, "the detection is counted for dashboards");
    assert_eq!(st.host, 1, "the serve lands in the host tier");
    assert!(!fused.last_was_fallback(), "host single-pass is fused, not per-op");
    assert_eq!(fused.last_launches(), 1);
}

#[test]
fn reduce_chains_are_refused_by_dense_only_engines_and_served_by_the_host_tier() {
    use fkl::exec::{Engine, FusedEngine, GraphEngine, HostFusedEngine, UnfusedEngine};
    use fkl::ops::ReduceKind;
    // a reduce-terminated chain: dense per-op engines cannot accumulate
    // anything and must refuse with typed errors; the artifact planner
    // refuses with the dedicated PlanError::Reduction; and every path that
    // reaches the host engine SERVES it — fold-while-reading, bit-equal to
    // the materializing oracle
    let p = fkl::chain::Chain::read::<fkl::chain::U8>(&[6, 4])
        .map(fkl::chain::Mul(0.5))
        .reduce(ReduceKind::Mean)
        .into_pipeline();
    let x = Tensor::from_u8(&(0..24).collect::<Vec<u8>>(), &[1, 6, 4]);
    let want = fkl::hostref::run_pipeline(&p, &x);

    // dense-only per-op engines: loud, typed refusal naming the terminator
    let unfused = UnfusedEngine::new(empty_registry());
    let err = unfused.run(&p, &x).unwrap_err();
    let t = err.downcast_ref::<fkl::exec::UnsupportedOp>().expect("typed refusal");
    assert_eq!(t.engine, "unfused");
    assert_eq!(t.token, "reduce[mean]");
    let graph = GraphEngine::new(empty_registry());
    let err = graph.run(&p, &x).unwrap_err();
    let t = err.downcast_ref::<fkl::exec::UnsupportedOp>().expect("typed refusal");
    assert_eq!(t.engine, "graph");

    // the artifact planner refuses with the dedicated typed variant
    let err = fkl::fusion::plan_pipeline(&p, &empty_registry(), "pallas").unwrap_err();
    assert!(
        matches!(err, fkl::fusion::PlanError::Reduction(ref tok) if tok == "reduce[mean]"),
        "{err}"
    );

    // the host engine serves natively ...
    let host = HostFusedEngine::with_threads(1);
    let got = host.run(&p, &x).expect("host tier folds while reading");
    assert_eq!(got, want);
    assert_eq!(host.reduce_runs(), 1);

    // ... and the fused front door detects (typed, counted) and re-routes
    let fused = FusedEngine::new(empty_registry());
    let got = fused.run(&p, &x).expect("fused front door re-routes to the host tier");
    assert_eq!(got, want);
    let st = fused.planner_stats();
    assert_eq!(st.reduction, 1, "the detection lands in the new reduce tier");
    assert_eq!(st.host, 1, "the serve lands in the host tier");
    assert!(!fused.last_was_fallback(), "fold-while-reading is fused, not per-op");
    assert_eq!(fused.last_launches(), 1);
}

#[test]
fn divergent_windows_are_refused_by_artifact_tiers_and_served_by_the_host_divergent_tier() {
    use fkl::exec::{Engine, FusedEngine, HostFusedEngine};
    use fkl::fusion::{plan_window, PlanError};
    use fkl::ops::ReduceKind;
    use fkl::tensor::{make_frame, Rect};
    // a window mixing three signatures: dense map, structured resize->split,
    // reduce seal — one artifact launch binds ONE code shape, so the window
    // planner must refuse with the dedicated typed variant
    let dense = fkl::chain::Chain::read::<fkl::chain::U8>(&[6, 4])
        .map(fkl::chain::Mul(2.0))
        .cast::<fkl::chain::F32>()
        .write()
        .into_pipeline();
    let structured = fkl::chain::Chain::read_resize::<fkl::chain::U8>(Rect::new(0, 0, 12, 8), 6, 4)
        .map(fkl::chain::CvtColor)
        .cast::<fkl::chain::F32>()
        .write_split()
        .into_pipeline();
    let reduce = fkl::chain::Chain::read::<fkl::chain::U8>(&[6, 4])
        .map(fkl::chain::Mul(0.5))
        .reduce(ReduceKind::Mean)
        .into_pipeline();
    let reg = empty_registry();
    let err = plan_window(&[&dense, &structured, &reduce], &reg, "pallas").unwrap_err();
    assert!(
        matches!(err, PlanError::Divergent(ref msg) if msg.contains("3 distinct")),
        "{err}"
    );
    // a homogeneous window is NOT divergent: it falls through to the
    // per-pipeline planner (here: no coverage in the empty registry)
    let err = plan_window(&[&dense, &dense], &reg, "pallas").unwrap_err();
    assert!(matches!(err, PlanError::NoCoverage { .. }), "{err}");

    // the fused front door detects the divergence (typed, counted) and
    // re-routes the WHOLE window to the host divergent tier — served in one
    // pass, bit-equal to the oracle
    let item = Tensor::from_u8(&(0..24).collect::<Vec<u8>>(), &[1, 6, 4]);
    let frame = make_frame(16, 20, 11);
    let window: Vec<(&fkl::ops::Pipeline, &Tensor)> =
        vec![(&dense, &item), (&structured, &frame), (&reduce, &item)];
    let fused = FusedEngine::new(empty_registry());
    let out = fused.run_many(&window);
    assert_eq!(out.launches, 1, "the divergent re-route is ONE pass");
    assert!(out.divergent_pass, "the outcome is marked as a genuine divergent pass");
    for (i, ((p, t), res)) in window.iter().zip(&out.results).enumerate() {
        let got = res.as_ref().expect("window item serves");
        assert_eq!(got, &fkl::hostref::run_pipeline(p, t), "item {i}");
    }
    let st = fused.planner_stats();
    assert_eq!(st.divergent, 1, "the detection lands in the divergent tier counter");
    assert_eq!(st.host, 3, "the per-item serves land in the host tier");
    assert!(!fused.last_was_fallback(), "divergent HF is fused, not per-op");

    // the host engine serves the same window natively, counted the same way
    let host = HostFusedEngine::with_threads(2);
    let out = host.run_divergent(&window);
    assert!(out.results.iter().all(|r| r.is_ok()));
    assert_eq!(out.distinct_signatures, 3);
    assert_eq!(host.divergent_runs(), 1);
    assert_eq!(host.reduce_runs(), 1);
    assert!(host.structured_runs() >= 1);
}

#[test]
fn host_engine_rejects_mismatched_inputs_loudly() {
    // the host fused backend applies the same fail-loudly contract: a dtype
    // mismatch is an error reply, never a silent cast, and the service keeps
    // serving afterwards
    let svc = Service::start(ServiceConfig {
        artifact_dir: Some("/definitely/not/here".into()),
        queue_cap: 8,
        policy: BatchPolicy::default(),
        engine: EngineSelect::HostFused,
        ..ServiceConfig::default()
    });
    let p = Pipeline::from_opcodes(&[(Opcode::Mul, 2.0)], &[4], 1, DType::U8, DType::U8)
        .unwrap();
    let wrong = svc.submit(p.clone(), Tensor::from_f32(&[0.0; 4], &[1, 4])).unwrap();
    let out = wrong.recv().unwrap();
    assert!(out.is_err(), "dtype mismatch must not silently run");
    let err = out.unwrap_err();
    assert!(
        matches!(err, fkl::coordinator::ServeError::BadItem(_)),
        "a malformed item is a typed client error: {err}"
    );
    assert!(err.to_string().contains("dtype"));

    let good = svc.submit(p, Tensor::from_u8(&[100; 4], &[1, 4])).unwrap();
    let t = good.recv().unwrap().expect("host backend keeps serving");
    assert_eq!(t.as_u8().unwrap(), &[200, 200, 200, 200]);
    let m = svc.metrics().unwrap();
    assert!(m.failed >= 1);
    assert_eq!(m.planner.host as u64 + m.failed, 2);
    svc.shutdown();
}
