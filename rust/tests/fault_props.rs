//! Fault-tolerance properties of the serving core, proven with the
//! deterministic fault-injection harness ([`fkl::faults`]).
//!
//! Everything here is attempt-counted — injected faults fire at fixed
//! launch indices, breaker probation counts rejected attempts, and batch
//! windows fill to `max_batch` before popping — so no test sleeps, races a
//! clock, or asserts on wall time.

use std::time::Duration;

use fkl::chain::{Add, Chain, Mul, F32, U8};
use fkl::coordinator::{
    BatchPolicy, BreakerPolicy, BreakerState, EngineSelect, ServeError, ServeTier, Service,
    ServiceConfig,
};
use fkl::faults::FaultPlan;
use fkl::ops::{Pipeline, Signature};
use fkl::tensor::Tensor;

/// The test traffic: a dense u8 chain whose stream key contains "mul".
fn mul_pipeline() -> Pipeline {
    Chain::read::<U8>(&[4, 5]).map(Mul(2.0)).cast::<F32>().write().into_pipeline()
}

fn add_pipeline() -> Pipeline {
    Chain::read::<U8>(&[4, 5]).map(Add(3.0)).cast::<F32>().write().into_pipeline()
}

fn item(fill: u8) -> Tensor {
    Tensor::from_u8(&[fill; 20], &[1, 4, 5])
}

/// `max_batch: 2` + a huge window = a group launches exactly when its two
/// requests are queued, never on a timer — window boundaries are decided by
/// the test, deterministically.
fn two_at_a_time(faults: &str, breaker: BreakerPolicy) -> Service {
    Service::start(ServiceConfig {
        artifact_dir: None,
        queue_cap: 64,
        policy: BatchPolicy { max_batch: 2, window: Duration::from_secs(600), ..Default::default() },
        engine: EngineSelect::HostFused,
        breaker,
        faults: Some(FaultPlan::parse(faults).expect("valid fault spec")),
        ..ServiceConfig::default()
    })
}

/// Submit the same pipeline twice (one full window) and collect both replies.
fn window(svc: &Service, p: &Pipeline) -> Vec<Result<Tensor, ServeError>> {
    let rxs: Vec<_> =
        (0..2).map(|i| svc.submit(p.clone(), item(10 + i)).expect("queue has room")).collect();
    rxs.into_iter().map(|rx| rx.recv().expect("service alive")).collect()
}

#[test]
fn from_env_honors_fkl_faults() {
    // CI runs this binary with FKL_FAULTS set; locally it is usually unset.
    // Either way from_env must agree with the environment — and a set spec
    // must parse through the same grammar as FaultPlan::parse.
    match std::env::var("FKL_FAULTS") {
        Ok(spec) if !spec.trim().is_empty() => {
            let plan = FaultPlan::from_env().expect("CI spec parses").expect("present");
            assert_eq!(plan, FaultPlan::parse(&spec).unwrap());
            assert!(!plan.is_empty());
        }
        _ => assert_eq!(FaultPlan::from_env().unwrap(), None),
    }
}

#[test]
fn service_config_does_not_read_the_environment() {
    // FKL_FAULTS (set by CI for this binary) must not leak into a service
    // whose config carries no plan: library users arm faults explicitly.
    let svc = Service::start(ServiceConfig {
        artifact_dir: None,
        queue_cap: 8,
        policy: BatchPolicy { max_batch: 2, window: Duration::from_secs(600), ..Default::default() },
        engine: EngineSelect::HostFused,
        ..ServiceConfig::default()
    });
    for r in window(&svc, &mul_pipeline()) {
        r.expect("no injection without an explicit plan");
    }
    let m = svc.metrics().unwrap();
    assert_eq!((m.failed, m.launch_panics), (0, 0));
    svc.shutdown();
}

/// The acceptance walk: a panic-injected stream demotes down the whole
/// ladder (stacked -> divergent -> per-item -> open), sits out probation,
/// probes back in and recovers tier by tier — while the service thread
/// survives every contained panic and the final replies are bit-equal to
/// the host oracle.
#[test]
fn panic_storm_walks_the_ladder_down_and_recovers() {
    let policy = BreakerPolicy {
        failure_threshold: 2,
        probation_attempts: 2,
        promote_successes: 2,
    };
    // launches 0..6 of the mul stream panic, at EVERY tier; launch 6 (the
    // half-open probe) and everything after succeed. `sig=mul` keeps the
    // build-tier consult (key "backend") out of the rule's counter.
    let svc = two_at_a_time("sig=mul,tier=any,launch=0..6,action=panic", policy);
    let p = mul_pipeline();
    let key = Signature::of(&p).stream_key();

    // W1+W2: two stacked launches panic -> contained, typed, demote to
    // divergent (one breaker event per LAUNCH, not per rider)
    for w in 0..2 {
        for r in window(&svc, &p) {
            match r {
                Err(ServeError::LaunchPanicked(msg)) => {
                    assert!(msg.contains("injected fault"), "window {w}: {msg}")
                }
                other => panic!("window {w}: want LaunchPanicked, got {other:?}"),
            }
        }
    }
    // W3: the divergent pass serves the window; both items' lanes panic and
    // fail ALONE (2 item-level breaker events) -> demote to per-item
    for r in window(&svc, &p) {
        assert!(matches!(r, Err(ServeError::LaunchPanicked(_))), "divergent item isolated");
    }
    // W4: two per-item launches panic -> breaker opens
    for r in window(&svc, &p) {
        assert!(matches!(r, Err(ServeError::LaunchPanicked(_))), "per-item isolated");
    }
    // W5: open breaker rejects the whole window, typed; rejected attempts
    // are the probation clock
    for r in window(&svc, &p) {
        match r {
            Err(ServeError::CircuitOpen { stream }) => assert_eq!(stream, key),
            other => panic!("want CircuitOpen, got {other:?}"),
        }
    }
    {
        let m = svc.metrics().unwrap();
        let b = m.breaker(&key).expect("tripped stream is in the snapshot");
        assert_eq!(b.state, BreakerState::Open);
        assert_eq!(m.breaker_trips, 3, "stacked->divergent->peritem->open");
    }
    // W6: probation served -> ONE half-open probe runs per item (launch 6:
    // no fault) and closes the breaker; its companion is rejected
    let w6 = window(&svc, &p);
    let oks = w6.iter().filter(|r| r.is_ok()).count();
    let rejected = w6
        .iter()
        .filter(|r| matches!(r, Err(ServeError::CircuitOpen { .. })))
        .count();
    assert_eq!((oks, rejected), (1, 1), "exactly one probe, company rejected: {w6:?}");
    // W7 -> per-item tier, W8 -> promoted to divergent, W9 -> fully
    // recovered to stacked; all serve cleanly
    for w in 7..=9 {
        for r in window(&svc, &p) {
            r.unwrap_or_else(|e| panic!("window {w} must serve: {e}"));
        }
    }
    let m = svc.metrics().unwrap();
    let b = m.breaker(&key).expect("history stays visible");
    assert_eq!(b.state, BreakerState::Closed);
    assert_eq!(b.tier, ServeTier::Stacked, "full recovery up the ladder");
    assert_eq!(m.breaker_trips, 3);
    assert_eq!(m.breaker_rejected, 3, "W5's two + W6's companion");
    assert_eq!(m.launch_panics, 6, "2 stacked + 2 divergent items + 2 per-item");
    assert_eq!(m.failed, 8, "every contained panic failed its riders, typed");
    assert_eq!(m.completed, 7, "probe + W7..W9");

    // the recovered stream serves bit-equal to the oracle
    let rx = svc.submit(p.clone(), item(42)).unwrap();
    let rx2 = svc.submit(p.clone(), item(43)).unwrap();
    let want = fkl::hostref::run_pipeline(&p, &item(42));
    let want2 = fkl::hostref::run_pipeline(&p, &item(43));
    assert_eq!(rx.recv().unwrap().unwrap(), want);
    assert_eq!(rx2.recv().unwrap().unwrap(), want2);
    svc.shutdown();
}

#[test]
fn stacked_panic_fails_only_its_stream_and_other_streams_keep_serving() {
    // one poisoned stacked launch of the mul stream; the add stream shares
    // the service and must be untouched
    let svc = two_at_a_time("sig=mul,tier=stacked,launch=0,action=panic", BreakerPolicy::default());
    let (pm, pa) = (mul_pipeline(), add_pipeline());
    for r in window(&svc, &pm) {
        assert!(matches!(r, Err(ServeError::LaunchPanicked(_))), "faulted stream fails typed");
    }
    for r in window(&svc, &pa) {
        let out = r.expect("innocent stream unaffected");
        assert_eq!(out.shape(), &[1, 4, 5]);
    }
    // the faulted stream recovers immediately (launch 1 has no fault)
    for r in window(&svc, &pm) {
        r.expect("next stacked launch serves");
    }
    let m = svc.metrics().unwrap();
    assert_eq!(m.launch_panics, 1, "one contained panic for the one poisoned launch");
    assert_eq!(m.failed, 2, "only the two riders of that launch");
    assert_eq!(m.completed, 4);
    let b = m.breaker(&Signature::of(&pm).stream_key()).expect("failure recorded");
    assert_eq!(b.state, BreakerState::Closed, "one failure is below the trip threshold");
    svc.shutdown();
}

#[test]
fn injected_error_faults_are_typed_not_panics() {
    // action=err takes the ordinary-error path: typed Exec reply carrying
    // the InjectedFault rendering, zero launch_panics
    let svc = two_at_a_time("sig=mul,tier=stacked,launch=0,action=err", BreakerPolicy::default());
    for r in window(&svc, &mul_pipeline()) {
        match r {
            Err(ServeError::Exec(msg)) => assert!(msg.contains("injected fault"), "{msg}"),
            other => panic!("want Exec(injected fault), got {other:?}"),
        }
    }
    let m = svc.metrics().unwrap();
    assert_eq!(m.launch_panics, 0);
    assert_eq!(m.failed, 2);
    svc.shutdown();
}

#[test]
fn divergent_window_item_fault_fails_alone_through_the_service() {
    // two different-signature singletons usually age out together and merge
    // into the window's shared divergent pass; a scheduling wakeup between
    // their deadlines may split them to per-item instead. The add stream's
    // FIRST launch is faulted at whichever tier serves it (tier=any), so
    // the assertions are deterministic under both layouts — and either way
    // the fault must fail the add item ALONE. (The divergent tier's
    // isolation contract is pinned deterministically, engine-level, by the
    // fuzz harness's fault extension.)
    let svc = Service::start(ServiceConfig {
        artifact_dir: None,
        queue_cap: 64,
        policy: BatchPolicy { max_batch: 8, window: Duration::from_millis(50), ..Default::default() },
        engine: EngineSelect::HostFused,
        faults: Some(FaultPlan::parse("sig=add,tier=any,launch=0,action=panic").unwrap()),
        ..ServiceConfig::default()
    });
    let (pm, pa) = (mul_pipeline(), add_pipeline());
    let rx_m = svc.submit(pm.clone(), item(7)).unwrap();
    let rx_a = svc.submit(pa.clone(), item(9)).unwrap();
    let out_m = rx_m.recv().unwrap().expect("survivor serves");
    assert_eq!(out_m, fkl::hostref::run_pipeline(&pm, &item(7)), "survivor bit-equal");
    match rx_a.recv().unwrap() {
        Err(ServeError::LaunchPanicked(msg)) => {
            assert!(msg.contains("injected fault"), "{msg}")
        }
        other => panic!("faulted item fails alone: {other:?}"),
    }
    let m = svc.metrics().unwrap();
    assert_eq!((m.completed, m.failed), (1, 1));
    svc.shutdown();
}

#[test]
fn supervisor_rebuilds_a_backend_whose_construction_panics() {
    // construction panics twice (launches 0..2), the third attempt builds;
    // the service then serves normally and reports the absorbed restarts
    let svc = Service::start(ServiceConfig {
        artifact_dir: None,
        queue_cap: 8,
        policy: BatchPolicy { max_batch: 2, window: Duration::from_secs(600), ..Default::default() },
        engine: EngineSelect::HostFused,
        faults: Some(FaultPlan::parse("tier=build,launch=0..2,action=panic").unwrap()),
        max_build_retries: 2,
        ..ServiceConfig::default()
    });
    for r in window(&svc, &mul_pipeline()) {
        r.expect("rebuilt backend serves");
    }
    let m = svc.metrics().unwrap();
    assert_eq!(m.supervisor_restarts, 2);
    assert_eq!(m.completed, 2);
    svc.shutdown();
}

#[test]
fn exhausted_supervisor_poisons_the_service_with_typed_unavailable() {
    let svc = Service::start(ServiceConfig {
        artifact_dir: None,
        queue_cap: 8,
        policy: BatchPolicy { max_batch: 2, window: Duration::from_secs(600), ..Default::default() },
        engine: EngineSelect::HostFused,
        faults: Some(FaultPlan::parse("tier=build,action=panic").unwrap()),
        max_build_retries: 1,
        ..ServiceConfig::default()
    });
    let rx = svc.submit(mul_pipeline(), item(1)).unwrap();
    match rx.recv().expect("poisoned service still answers") {
        Err(ServeError::Unavailable(msg)) => {
            assert!(msg.contains("construction kept failing"), "{msg}")
        }
        other => panic!("want Unavailable, got {other:?}"),
    }
    let m = svc.metrics().expect("poisoned service still snapshots");
    assert_eq!(m.supervisor_restarts, 2, "budget of 1 retry = 2 failed attempts");
    assert!(m.degraded.is_some(), "poison reason surfaces structurally");
    svc.shutdown();
}

#[test]
fn deadlines_shed_at_ingress_and_expire_at_pop() {
    // Shed vs Expired boundary: Shed = admission control refused it at
    // ingest (judged against Instant::now() — a request that aged past its
    // deadline in the ingress channel counts); Expired = it was queued live
    // and the deadline passed before its group launched.
    let svc = Service::start(ServiceConfig {
        artifact_dir: None,
        queue_cap: 64,
        policy: BatchPolicy { max_batch: 3, window: Duration::from_secs(600), ..Default::default() },
        engine: EngineSelect::HostFused,
        ..ServiceConfig::default()
    });
    let p = add_pipeline();
    // warm up: backend construction happens before any deadline is ticking
    let warm: Vec<_> = (0..3).map(|i| svc.submit(mul_pipeline(), item(9 + i)).unwrap()).collect();
    for rx in warm {
        rx.recv().unwrap().expect("warmup serves");
    }

    // dead on arrival -> shed at ingress, before ever queueing
    let doa = svc.submit_with_deadline(p.clone(), item(1), Duration::ZERO).unwrap();
    assert!(matches!(doa.recv().unwrap(), Err(ServeError::Shed)));
    // a 1ns deadline always lapses during the channel hop: also SHED (the
    // DOA check judges against now, not the enqueue instant — the old
    // enqueued-time check let these through to die as Expired later)
    let nano = svc.submit_with_deadline(p.clone(), item(2), Duration::from_nanos(1)).unwrap();
    assert!(matches!(nano.recv().unwrap(), Err(ServeError::Shed)));

    // deterministic Expired: the victim (stream Y, tight deadline) and a
    // generous rider are queued LIVE but the group stays under max_batch, so
    // it can only pop on the victim's deadline wake; meanwhile three big
    // blockers FILL stream X, which pops immediately and occupies the
    // single service thread far longer than the victim's deadline. All
    // items are pre-built so the submits land within microseconds.
    let slow = Chain::read::<F32>(&[2048, 4096])
        .map(Mul(1.01))
        .map(Add(0.5))
        .map(Mul(0.99))
        .write()
        .into_pipeline();
    let big = vec![1.0f32; 2048 * 4096];
    let blocker_items: Vec<Tensor> =
        (0..3).map(|_| Tensor::from_f32(&big, &[1, 2048, 4096])).collect();
    let victim =
        svc.submit_with_deadline(p.clone(), item(3), Duration::from_millis(5)).unwrap();
    // the rider shares the victim's group, pops with it, and serves
    let rider = svc.submit_with_deadline(p.clone(), item(4), Duration::from_secs(600)).unwrap();
    let blockers: Vec<_> =
        blocker_items.into_iter().map(|t| svc.submit(slow.clone(), t).unwrap()).collect();
    assert!(matches!(victim.recv().unwrap(), Err(ServeError::Expired)));
    assert_eq!(rider.recv().unwrap().unwrap(), fkl::hostref::run_pipeline(&p, &item(4)));
    for rx in blockers {
        rx.recv().unwrap().expect("blocker serves");
    }

    let m = svc.metrics().unwrap();
    assert_eq!((m.shed, m.expired, m.completed), (1 + 1, 1, 3 + 3 + 1));
    assert_eq!(m.deadline_margin.count, 1, "margin recorded for the served deadline request");
    assert!(m.est_item_us > 0.0, "the admission EWMA learned from the served launches");
    // shed and expired requests record latency like every other resolution
    assert!(
        m.latency_hist.count() >= m.completed + m.shed + m.expired,
        "every resolution observes latency"
    );
    svc.shutdown();
}

#[test]
fn default_deadline_applies_to_plain_submit() {
    let svc = Service::start(ServiceConfig {
        artifact_dir: None,
        queue_cap: 8,
        policy: BatchPolicy { max_batch: 64, window: Duration::from_millis(2), ..Default::default() },
        engine: EngineSelect::HostFused,
        default_deadline: Some(Duration::ZERO),
        ..ServiceConfig::default()
    });
    // every plain submit inherits the configured deadline: ZERO = DOA
    let rx = svc.submit(mul_pipeline(), item(1)).unwrap();
    assert!(matches!(rx.recv().unwrap(), Err(ServeError::Shed)));
    // an explicit deadline overrides the default
    let rx = svc.submit_with_deadline(mul_pipeline(), item(2), Duration::from_secs(600)).unwrap();
    rx.recv().unwrap().expect("explicit deadline serves");
    let m = svc.metrics().unwrap();
    assert_eq!((m.shed, m.completed), (1, 1));
    svc.shutdown();
}
