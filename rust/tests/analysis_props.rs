//! Property tests for the static analyzer (`fkl::analysis`), driven by the
//! in-tree `proplite` harness over randomly generated — but always valid —
//! pipelines:
//!
//! * canonicalization is IDEMPOTENT (the canonical twin is a fixpoint);
//! * the canonical signature depends only on the chain's STRUCTURE — two
//!   pipelines differing only in (identity-free) parameter values
//!   canonicalize to the same signature;
//! * canonicalization never touches the reduce seal, the read/write
//!   patterns, dtypes, shape or batch — rewrites happen strictly inside
//!   the compute body;
//! * lint is PURE: it never mutates its input and is deterministic.

use fkl::analysis::{canonicalize, lint};
use fkl::ops::{
    IOp, MemOp, Opcode, Pipeline, ReduceAxis, ReduceSpec, Signature, ALL_OPCODES,
    ALL_REDUCE_KINDS,
};
use fkl::proplite::{forall, Rng};
use fkl::tensor::{DType, Rect};

const ALL_DTYPES: [DType; 5] = [DType::U8, DType::U16, DType::I32, DType::F32, DType::F64];

/// One random valid pipeline over the whole IR vocabulary: dense / crop
/// reads, dense / split writes and reduce seals, scalar / lane-structured
/// bodies — salted with removable identities and Neg;Neg pairs so the
/// canonicalizer has real work on a good fraction of the cases.
fn gen_pipeline(rng: &mut Rng) -> Pipeline {
    let dtin = *rng.pick(&ALL_DTYPES);
    let batch = rng.usize(1, 4);
    let structured = rng.usize(0, 4) == 0;
    let (read, shape) = if structured {
        let rect = Rect::new(
            rng.usize(0, 10) as i32,
            rng.usize(0, 10) as i32,
            rng.usize(1, 7) as i32,
            rng.usize(1, 7) as i32,
        );
        let shape = vec![rect.h as usize, rect.w as usize, 3];
        (MemOp::CropRead { rect }, shape)
    } else if rng.bool() {
        (MemOp::Read { dtype: dtin }, vec![rng.usize(1, 6), rng.usize(1, 6), 3])
    } else {
        (MemOp::Read { dtype: dtin }, vec![rng.usize(1, 8), rng.usize(1, 8)])
    };
    let pixel = shape.len() == 3 && shape[2] == 3;
    let (term, dtout) = match rng.usize(0, 4) {
        0 => {
            let axis = if rng.bool() { ReduceAxis::Full } else { ReduceAxis::PerChannel };
            let spec = ReduceSpec::single(*rng.pick(&ALL_REDUCE_KINDS), axis);
            (MemOp::Reduce { spec }, DType::F64)
        }
        1 if pixel => {
            let d = *rng.pick(&ALL_DTYPES);
            (MemOp::SplitWrite { dtype: d }, d)
        }
        _ => {
            let d = *rng.pick(&ALL_DTYPES);
            (MemOp::Write { dtype: d }, d)
        }
    };
    let k = rng.usize(1, 9);
    let mut ops = vec![IOp::Mem(read)];
    for _ in 0..k {
        match rng.usize(0, 6) {
            0 => ops.push(IOp::compute(*rng.pick(&[Opcode::Mul, Opcode::Div]), 1.0)),
            1 => ops.push(IOp::compute(Opcode::Sub, 0.0)),
            2 => {
                ops.push(IOp::compute(Opcode::Neg, 0.0));
                ops.push(IOp::compute(Opcode::Neg, 0.0));
            }
            3 => ops.push(IOp::CvtColor),
            _ => {
                let op = *rng.pick(&ALL_OPCODES);
                ops.push(IOp::compute(op, rng.f64(-3.0, 3.0)));
            }
        }
    }
    ops.push(IOp::Mem(term));
    Pipeline::new(ops, shape, batch, dtin, dtout).expect("generated pipelines are valid")
}

#[test]
fn canonicalize_is_idempotent_on_random_pipelines() {
    forall(60, |rng| {
        let p = gen_pipeline(rng);
        let (c1, _) = canonicalize(p);
        let (c2, again) = canonicalize(c1.clone());
        assert_eq!(c2, c1, "the canonical twin is a fixpoint");
        assert!(again.iter().all(|r| !r.applied), "second pass re-applied: {again:?}");
    });
}

#[test]
fn canonical_signature_is_stable_under_param_renaming() {
    forall(60, |rng| {
        // one op STRUCTURE, two parameter draws from the identity-free
        // range (|p| in [1.25, 3]: never 0, 1, inf or NaN) — which stages
        // the canonicalizer removes depends only on the structure, so both
        // twins must land on the SAME canonical signature
        let k = rng.usize(1, 9);
        let structure: Vec<Opcode> = (0..k).map(|_| *rng.pick(&ALL_OPCODES)).collect();
        let draw = |rng: &mut Rng| {
            let mag = rng.f64(1.25, 3.0);
            if rng.bool() {
                mag
            } else {
                -mag
            }
        };
        let a: Vec<f64> = (0..k).map(|_| draw(rng)).collect();
        let b: Vec<f64> = (0..k).map(|_| draw(rng)).collect();
        let mk = |params: &[f64]| {
            let ops: Vec<(Opcode, f64)> =
                structure.iter().copied().zip(params.iter().copied()).collect();
            Pipeline::from_opcodes(&ops, &[4, 5], 1, DType::U8, DType::F64).unwrap()
        };
        let (ca, _) = canonicalize(mk(&a));
        let (cb, _) = canonicalize(mk(&b));
        assert_eq!(
            Signature::of(&ca),
            Signature::of(&cb),
            "canonical signature must depend only on structure: {structure:?} {a:?} {b:?}"
        );
    });
}

#[test]
fn canonicalize_never_touches_seals_boundaries_or_geometry() {
    forall(80, |rng| {
        let p = gen_pipeline(rng);
        let (c, _) = canonicalize(p.clone());
        assert_eq!(c.reduction(), p.reduction(), "reduce seal preserved");
        assert_eq!(c.read_pattern(), p.read_pattern(), "read pattern preserved");
        assert_eq!(c.write_pattern(), p.write_pattern(), "write pattern preserved");
        assert_eq!(c.dtin, p.dtin);
        assert_eq!(c.dtout, p.dtout);
        assert_eq!(c.shape, p.shape);
        assert_eq!(c.batch, p.batch);
        assert!(!c.body().is_empty(), "canonicalization never empties the body");
    });
}

#[test]
fn predicted_lane_width_matches_the_compiled_plan() {
    use fkl::analysis::predict_tier;
    use fkl::fusion::HostPlan;
    use fkl::ops::kernel::{LANE_WIDTH_F32, LANE_WIDTH_F64, REDUCE_LANES};
    forall(80, |rng| {
        // the static prediction and the plan the engine actually runs must
        // name the SAME register-block width, over the whole generator
        // vocabulary (dense/structured reads, split writes, reduce seals,
        // scalar and lane-grouped bodies, all 5 dtype pairs)
        let p = gen_pipeline(rng);
        let plan = HostPlan::compile(&p);
        let t = predict_tier(&p);
        assert_eq!(
            t.lane_width,
            plan.vectorization(),
            "FKL008 width must match the compiled plan ({:?})",
            Signature::of(&p)
        );
        // and the plan's width follows the published rule
        let want = if p.reduction().is_some() {
            REDUCE_LANES as u8
        } else if plan.accum() == fkl::fusion::HostAccum::F32 {
            LANE_WIDTH_F32 as u8
        } else {
            LANE_WIDTH_F64 as u8
        };
        assert_eq!(plan.vectorization(), want, "width rule drifted");
        assert!(t.lane_width > 1, "compiled plans never record the scalar arm");
        // the predicted byte model is the same one the engine accounts with
        assert_eq!(
            t.bytes_fused,
            (plan.bytes_read() + plan.bytes_written()) as u64,
            "FKL008 fused bytes must match the compiled plan"
        );
        assert_eq!(t.bytes_baseline, p.baseline_bytes() as u64);
        assert!(t.fusion_efficiency() >= 0.99, "fusion must never predict a byte regression");
    });
}

#[test]
fn lint_is_pure_and_deterministic() {
    forall(60, |rng| {
        let p = gen_pipeline(rng);
        let before = p.clone();
        let d1 = lint(&p);
        let d2 = lint(&p);
        assert_eq!(p, before, "lint must not mutate the pipeline");
        assert_eq!(d1, d2, "lint is deterministic");
        // every run ends with the tier prediction (FKL008)
        assert_eq!(d1.last().expect("never empty").code.code(), "FKL008");
    });
}
