//! End-to-end tests for the sharded coordinator: N workers behind the
//! stream-key-hash router must be observationally identical to the single
//! worker — same replies bit-for-bit — while the merged metrics stay
//! internally consistent (per-shard rows sum to the totals).

use std::time::Duration;

use fkl::chain::{Chain, Mul, F32, U8};
use fkl::coordinator::{BatchPolicy, Service, ServiceConfig};
use fkl::hostref;
use fkl::ops::Pipeline;
use fkl::proplite::Rng;
use fkl::tensor::Tensor;

fn svc(shards: usize, window: Duration) -> Service {
    Service::start(ServiceConfig {
        artifact_dir: None,
        queue_cap: 512,
        policy: BatchPolicy { max_batch: 8, window, ..Default::default() },
        shards,
        ..ServiceConfig::default()
    })
}

/// Four distinct stream keys (the shape is the key): enough for a 4-shard
/// router to have something to spread.
fn workload(n: usize) -> Vec<(Pipeline, Tensor)> {
    let mut rng = Rng::new(41);
    let pipes: Vec<(usize, Pipeline)> = (0..4)
        .map(|s| {
            let w = 10 + s;
            let p = Chain::read::<U8>(&[10, w])
                .map(Mul(0.5 + s as f64))
                .cast::<F32>()
                .write()
                .into_pipeline();
            (w, p)
        })
        .collect();
    (0..n)
        .map(|i| {
            let (w, p) = &pipes[i % pipes.len()];
            (p.clone(), Tensor::from_u8(&rng.vec_u8(10 * w), &[1, 10, *w]))
        })
        .collect()
}

fn serve_all(svc: &Service, reqs: &[(Pipeline, Tensor)]) -> Vec<Tensor> {
    let rxs: Vec<_> = reqs
        .iter()
        .map(|(p, t)| svc.submit(p.clone(), t.clone()).expect("admitted"))
        .collect();
    rxs.into_iter()
        .map(|rx| rx.recv().expect("service alive").expect("request ok"))
        .collect()
}

#[test]
fn sharded_replies_are_bit_equal_to_single_shard_and_oracle() {
    let reqs = workload(48);
    let sharded = svc(4, Duration::from_micros(300));
    let outs4 = serve_all(&sharded, &reqs);
    sharded.shutdown();
    let single = svc(1, Duration::from_micros(300));
    let outs1 = serve_all(&single, &reqs);
    single.shutdown();
    for (i, ((p, t), (o4, o1))) in reqs.iter().zip(outs4.iter().zip(&outs1)).enumerate() {
        let want = hostref::run_pipeline(p, t);
        assert_eq!(*o4, want, "request {i}: 4-shard reply bit-equal to the oracle");
        assert_eq!(o4, o1, "request {i}: sharding changes nothing observable");
    }
}

#[test]
fn merged_metrics_rows_sum_to_the_totals() {
    let reqs = workload(64);
    let s = svc(4, Duration::from_micros(300));
    let outs = serve_all(&s, &reqs);
    assert_eq!(outs.len(), 64);
    let m = s.metrics().expect("merged snapshot");
    assert_eq!(m.completed, 64, "all requests served");
    assert_eq!(m.shards.len(), 4, "one row per shard");
    for (i, row) in m.shards.iter().enumerate() {
        assert_eq!(row.shard, i as u64, "rows sorted by shard id");
        assert_eq!(row.pending, 0, "drained service has no queued work");
    }
    let sum: u64 = m.shards.iter().map(|r| r.completed).sum();
    assert_eq!(sum, m.completed, "per-shard completions sum to the merged total");
    let occ: f64 = m.shards.iter().map(|r| r.occupancy).sum();
    assert!((occ - 1.0).abs() < 1e-9, "occupancy shares sum to 1: {occ}");
    // steal accounting: every steal event moves at least one request, and
    // the merged counters are the row sums
    assert!(m.stolen_requests >= m.steals, "steals={} stolen={}", m.steals, m.stolen_requests);
    let steals: u64 = m.shards.iter().map(|r| r.steals).sum();
    assert_eq!(steals, m.steals);
    // latency percentiles survive the histogram merge seam
    assert!(m.latency.p50 <= m.latency.p99 && m.latency.p99 <= m.latency.p999);
    assert!(m.latency.max > 0, "64 served requests left a latency distribution");
    s.shutdown();
}

#[test]
fn sharded_shutdown_drains_admitted_work() {
    // a long window parks everything in the batchers; shutdown must still
    // resolve every admitted reply (flush serves, never abandons)
    let reqs = workload(24);
    let s = svc(4, Duration::from_secs(60));
    let rxs: Vec<_> = reqs
        .iter()
        .map(|(p, t)| s.submit(p.clone(), t.clone()).expect("admitted"))
        .collect();
    s.shutdown();
    for (i, rx) in rxs.into_iter().enumerate() {
        let out = rx.recv().unwrap_or_else(|_| panic!("request {i}: reply dropped"));
        let (p, t) = &reqs[i];
        assert_eq!(out.expect("served live"), hostref::run_pipeline(p, t), "request {i}");
    }
}

#[test]
fn snapshot_probes_work_mid_serve_and_repeatedly() {
    // a snapshot is a control message: it must work while requests flow,
    // and repeated probes must be monotone in the counters
    let s = svc(4, Duration::from_micros(200));
    let reqs = workload(8);
    let _ = serve_all(&s, &reqs);
    let m1 = s.metrics().expect("first probe");
    let _ = serve_all(&s, &reqs);
    let m2 = s.metrics().expect("second probe");
    assert_eq!(m1.completed, 8);
    assert_eq!(m2.completed, 16, "counters accumulate across probes");
    assert!(m2.latency_hist.count() >= m1.latency_hist.count());
    s.shutdown();
}
