//! The core semantics invariant: FUSION NEVER CHANGES NUMERICS.
//!
//! fused == graph == unfused == hostref for f32 chains (exact compute path);
//! u8 chains compare with saturation-aware tolerances (the unfused engine
//! saturates at every step boundary — exactly like OpenCV — which is a
//! *semantic* difference the paper inherits too, so u8 equivalence is
//! checked against the step-saturating oracle).
#![cfg(feature = "pjrt")] // drives AOT artifacts through the PJRT runtime

use std::rc::Rc;

use fkl::exec::Engine;
use fkl::hostref;
use fkl::ops::{Opcode, Pipeline};
use fkl::proplite::Rng;
use fkl::runtime::Registry;
use fkl::tensor::{DType, Tensor};

fn ctx() -> fkl::cv::Context {
    // XLA pinned: these tests drive the AOT artifact family
    fkl::cv::Context::with_select(fkl::exec::EngineSelect::Xla, None)
        .expect("run `make artifacts` first")
}

fn assert_close(got: &Tensor, want: &Tensor, tol: f64, what: &str) {
    assert_eq!(got.shape(), want.shape(), "{what}: shape");
    let g = got.to_f64_vec();
    let w = want.to_f64_vec();
    for (i, (a, b)) in g.iter().zip(&w).enumerate() {
        assert!((a - b).abs() <= tol + tol * b.abs(), "{what} elem {i}: {a} vs {b}");
    }
}

#[test]
fn cmsd_f32_all_engines_agree_with_hostref() {
    let c = ctx();
    let p = Pipeline::from_opcodes(
        &[(Opcode::Nop, 0.0), (Opcode::Mul, 0.5), (Opcode::Sub, 3.0), (Opcode::Div, 1.7)],
        &[60, 120],
        50,
        DType::F32,
        DType::F32,
    )
    .unwrap();
    let mut rng = Rng::new(17);
    let input = Tensor::from_f32(&rng.vec_f32(50 * 60 * 120, -4.0, 4.0), &[50, 60, 120]);
    let want = hostref::run_pipeline(&p, &input);
    for engine in [c.fused().unwrap() as &dyn Engine, c.unfused().unwrap(), c.graph().unwrap()] {
        let got = engine.run(&p, &input).unwrap();
        assert_close(&got, &want, 1e-4, engine.name());
    }
}

#[test]
fn u8_unfused_matches_step_saturating_oracle() {
    let c = ctx();
    let p = Pipeline::from_opcodes(
        &[(Opcode::Mul, 2.0), (Opcode::Add, 7.0)],
        &[60, 120],
        1,
        DType::U8,
        DType::U8,
    )
    .unwrap();
    let mut rng = Rng::new(23);
    let input = Tensor::from_u8(&rng.vec_u8(60 * 120), &[1, 60, 120]);
    let got = c.unfused().unwrap().run(&p, &input).unwrap();
    let want = hostref::run_unfused(&p, &input);
    assert_close(&got, &want, 1.0, "unfused u8");

    // and fused matches the single-saturation oracle
    let gotf = c.fused().unwrap().run(&p, &input).unwrap();
    let wantf = hostref::run_pipeline(&p, &input);
    assert_close(&gotf, &wantf, 1.0, "fused u8");
}

#[test]
fn random_covered_chains_property() {
    // property: for chains the artifact family covers via the interpreter
    // tier (f32 256x256), fused == hostref on random programs
    let c = ctx();
    let mut rng = Rng::new(99);
    let safe_ops =
        [Opcode::Mul, Opcode::Add, Opcode::Sub, Opcode::Abs, Opcode::Min, Opcode::Max];
    for case in 0..10 {
        let k = rng.usize(1, 9);
        let chain: Vec<(Opcode, f64)> =
            (0..k).map(|_| (*rng.pick(&safe_ops), rng.f64(0.5, 1.5))).collect();
        let p = Pipeline::from_opcodes(&chain, &[256, 256], 1, DType::F32, DType::F32).unwrap();
        let input = Tensor::from_f32(&rng.vec_f32(256 * 256, -2.0, 2.0), &[1, 256, 256]);
        let got = c.fused().unwrap().run(&p, &input).unwrap();
        let want = hostref::run_pipeline(&p, &input);
        assert_close(&got, &want, 1e-3, &format!("case {case} chain {chain:?}"));
    }
}

#[test]
fn staticloop_tier_equals_explicit_chain() {
    // mul-add repeated n times must give identical results whether planned
    // as a StaticLoop (runtime trip) or evaluated by hostref step by step
    let c = ctx();
    let mut rng = Rng::new(7);
    let input = Tensor::from_u8(&rng.vec_u8(60 * 120 * 50), &[50, 60, 120]);
    for n in [1usize, 3, 17] {
        let mut chain = Vec::new();
        for _ in 0..n {
            chain.push((Opcode::Mul, 0.95));
            chain.push((Opcode::Add, 1.0));
        }
        let p = Pipeline::from_opcodes(&chain, &[60, 120], 50, DType::U8, DType::U8).unwrap();
        let plan = c.fused().unwrap().plan_for(&p).unwrap();
        assert_eq!(plan.tier(), "staticloop", "n={n}");
        let got = c.fused().unwrap().run(&p, &input).unwrap();
        let want = hostref::run_pipeline(&p, &input);
        assert_close(&got, &want, 1.0, &format!("staticloop n={n}"));
    }
}

#[test]
fn dtype_combos_fused_matches_oracle() {
    let c = ctx();
    let mut rng = Rng::new(41);
    for (dtin, dtout) in [
        (DType::U8, DType::F32),
        (DType::U16, DType::F32),
        (DType::F32, DType::F64),
        (DType::F64, DType::F64),
        (DType::F32, DType::U8),
    ] {
        let p = Pipeline::from_opcodes(
            &[(Opcode::Nop, 0.0), (Opcode::Mul, 0.5), (Opcode::Sub, 3.0), (Opcode::Div, 1.7)],
            &[60, 120],
            50,
            dtin,
            dtout,
        )
        .unwrap();
        let input = match dtin {
            DType::U8 => Tensor::from_u8(&rng.vec_u8(50 * 7200), &[50, 60, 120]),
            DType::U16 => {
                let v: Vec<u16> =
                    (0..50 * 7200).map(|_| (rng.next_u64() & 0xFFF) as u16).collect();
                Tensor::from_u16(&v, &[50, 60, 120])
            }
            _ => {
                let v: Vec<f64> = (0..50 * 7200).map(|_| rng.f64(0.0, 100.0)).collect();
                Tensor::from_f64_cast(&v, &[50, 60, 120], dtin)
            }
        };
        let got = c.fused().unwrap().run(&p, &input).unwrap();
        let want = hostref::run_pipeline(&p, &input);
        let tol = if dtout.is_int() { 1.0 } else { 1e-3 };
        assert_close(&got, &want, tol, &format!("{dtin}->{dtout}"));
    }
}

#[test]
fn chain_built_pipelines_agree_with_hostref_on_every_engine() {
    // the typed front door lowers to the same IR: fused == graph == unfused
    // == hostref for a chain built through fkl::chain (epsilon on the f32
    // path, the same tolerance the untyped suite grants)
    use fkl::chain::{Chain, ConvertTo, Div, Mul, Sub, F32, U8};
    let c = ctx();
    let typed = Chain::read::<U8>(&[60, 120])
        .batch(50)
        .map(ConvertTo)
        .map(Mul(0.5))
        .map(Sub(3.0))
        .map(Div(1.7))
        .cast::<F32>()
        .write();
    let p = typed.pipeline();
    let mut rng = Rng::new(53);
    let input = Tensor::from_u8(&rng.vec_u8(50 * 60 * 120), &[50, 60, 120]);
    let want = hostref::run_pipeline(p, &input);
    for engine in [c.fused().unwrap() as &dyn Engine, c.unfused().unwrap(), c.graph().unwrap()] {
        let got = engine.run(p, &input).unwrap();
        assert_close(&got, &want, 1e-3, engine.name());
    }
    // and the host engine's monomorphized path agrees too
    let host = fkl::exec::HostFusedEngine::new();
    let got = typed.run_host(&host, &input).unwrap();
    assert_close(&got, &want, 1e-3, "host run_mono");
}

#[test]
fn registry_is_shared_across_engines() {
    let reg = Rc::new(Registry::load(fkl::default_artifact_dir()).unwrap());
    let e1 = fkl::exec::FusedEngine::new(reg.clone());
    let _ = e1;
    assert!(Rc::strong_count(&reg) >= 2);
}
