//! Regression test for the async-upload lifetime bug: buffer_from_host_literal
//! copies asynchronously, so the source Literal must be kept alive by
//! DeviceValue. Hammering chained execute_b catches regressions.
#![cfg(feature = "pjrt")] // drives AOT artifacts through the PJRT runtime
use fkl::runtime::{DeviceValue, Executor, Registry};
use fkl::tensor::Tensor;
use std::rc::Rc;

#[test]
fn chained_execute_b_hammer() {
    let reg = Rc::new(Registry::load(fkl::default_artifact_dir()).unwrap());
    let exec = Executor::new(reg.clone());
    let name = "single_op_mul_u82u8_60x120_b1_pallas";
    let x = Tensor::from_u8(&vec![7u8; 7200], &[1, 60, 120]);
    let p = Tensor::from_f32(&[1.0], &[1]);
    let xb = DeviceValue::upload(&x).unwrap();
    let pb = DeviceValue::upload(&p).unwrap();
    let o1 = exec.run_b(name, &[&xb.buf, &pb.buf]).unwrap();
    let mut cur = DeviceValue::from_buffer(o1);
    let mut spent = Vec::new(); // intermediates must outlive the final sync
    for _ in 0..300 {
        let next = DeviceValue::from_buffer(exec.run_b(name, &[&cur.buf, &pb.buf]).unwrap());
        spent.push(std::mem::replace(&mut cur, next));
    }
    let out = cur.download().unwrap();
    drop(spent);
    assert_eq!(out.as_u8().unwrap(), x.as_u8().unwrap());
}
