//! End-to-end request tracing properties (`fkl::trace`), proven against the
//! real coordinator:
//!
//! * every traced request closes ONE well-formed span tree — root present,
//!   parents opened before children, request-scoped ids unique, child stage
//!   durations summing to within the root's queue-to-reply time;
//! * tracing off is free: serving without a tracer is bit-identical to
//!   serving with one (same tensors, same byte accounting);
//! * fault-injected requests still trace COMPLETE trees, with the typed
//!   error recorded on the failing span (the launch, when one ran);
//! * the capture exports as Chrome trace-event JSON that round-trips
//!   through the in-crate [`fkl::jsonlite`] parser;
//! * the fusion-efficiency counters surface the paper's headline ratio:
//!   ≈(k+1)/2× for a dense chain-k, exactly 1.0 for chain-1.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use fkl::chain::{Add, Chain, ConvertTo, CvtColor, Div, Mul, MulC3, Sub, F32, U8};
use fkl::coordinator::{BatchPolicy, EngineSelect, Service, ServiceConfig};
use fkl::faults::FaultPlan;
use fkl::ops::{Pipeline, ReduceKind};
use fkl::proplite::Rng;
use fkl::tensor::{make_frame, Rect, Tensor};
use fkl::trace::{SpanRecord, Stage, Tracer, NO_PARENT, TIER_DIVERGENT, TIER_STACKED};

/// The stacked company: a dense chain-5 u8->f32 stream (fused pass moves
/// 5 bytes/elem where op-at-a-time moves 21 — the 4.2x ideal).
fn chain5() -> Pipeline {
    Chain::read::<U8>(&[8, 9])
        .map(ConvertTo)
        .map(Mul(0.5))
        .map(Sub(3.0))
        .map(Div(1.7))
        .cast::<F32>()
        .write()
        .into_pipeline()
}

fn chain1() -> Pipeline {
    Chain::read::<U8>(&[8, 9]).map(ConvertTo).cast::<F32>().write().into_pipeline()
}

fn dense_item(rng: &mut Rng) -> Tensor {
    Tensor::from_u8(&rng.vec_u8(72), &[1, 8, 9])
}

/// Group the ring by request id, dropping the untraced sentinel.
fn by_request(spans: &[SpanRecord]) -> HashMap<u64, Vec<SpanRecord>> {
    let mut trees: HashMap<u64, Vec<SpanRecord>> = HashMap::new();
    for s in spans {
        assert_ne!(s.req, 0, "0 is the untraced sentinel, never recorded");
        trees.entry(s.req).or_default().push(*s);
    }
    trees
}

/// The well-formedness contract of one request's span tree.
fn assert_tree_wellformed(req: u64, tree: &[SpanRecord]) {
    // request-scoped span ids are unique: the tree closed exactly once
    let mut ids: Vec<u16> = tree.iter().map(|s| s.id).collect();
    ids.sort_unstable();
    let deduped = ids.len();
    ids.dedup();
    assert_eq!(ids.len(), deduped, "req {req}: duplicate span ids: {tree:?}");

    let get = |id: u16| tree.iter().find(|s| s.id == id);
    let root = get(0).unwrap_or_else(|| panic!("req {req}: no root span: {tree:?}"));
    assert_eq!(root.stage, Stage::Request);
    assert_eq!(root.parent, NO_PARENT, "the root has no parent");

    // every non-root span's parent exists and was opened no later than it
    for s in tree {
        if s.id == 0 {
            continue;
        }
        let parent = get(s.parent).unwrap_or_else(|| {
            panic!("req {req}: span {} orphaned (parent {}): {tree:?}", s.id, s.parent)
        });
        assert!(
            parent.start_us <= s.start_us,
            "req {req}: parent {} opened after child {}",
            parent.id,
            s.id
        );
        let (child_end, root_end) = (s.start_us + s.dur_us, root.start_us + root.dur_us);
        assert!(child_end <= root_end, "req {req}: span {} outlives the root", s.id);
    }

    // a request that reached a reply closed every sequential stage, and the
    // stage durations account for (at most) the root's queue-to-reply time
    let stages = [(1u16, Stage::Admit), (2, Stage::Queue), (3, Stage::Tier), (6, Stage::Reply)];
    for (id, stage) in stages {
        let s = get(id).unwrap_or_else(|| panic!("req {req}: missing {} span", stage.name()));
        assert_eq!(s.stage, stage, "req {req}: span id {id} has the wrong stage");
    }
    let sequential: u64 = [1u16, 2, 3, 6].iter().map(|&id| get(id).unwrap().dur_us).sum();
    assert!(
        sequential <= root.dur_us,
        "req {req}: stages sum to {sequential}us > root {}us",
        root.dur_us
    );
}

/// The acceptance window: stacked chain-5 company, a divergent mix (param
/// twin, lane-structured, resize->split, reduce) and ONE fault-injected
/// stream, served with tracing armed — every request closes a well-formed
/// tree, the failing request records its error on the launch span, and the
/// whole capture exports as Chrome trace events that round-trip.
#[test]
fn traced_mixed_window_closes_wellformed_span_trees() {
    let tracer = Arc::new(Tracer::new());
    let svc = Service::start(ServiceConfig {
        artifact_dir: None,
        queue_cap: 64,
        policy: BatchPolicy { max_batch: 32, window: Duration::from_millis(25), ..Default::default() },
        engine: EngineSelect::HostFused,
        // the `add` stream (and only it) errors at every launch tier
        faults: Some(FaultPlan::parse("sig=add,tier=any,launch=*,action=err").unwrap()),
        tracing: Some(tracer.clone()),
        ..ServiceConfig::default()
    });
    let mk_mul = |mul: f64| {
        Chain::read::<U8>(&[8, 9]).map(Mul(mul)).cast::<F32>().write().into_pipeline()
    };
    let lanes = Chain::read::<U8>(&[4, 3, 3])
        .map(CvtColor)
        .map(MulC3([0.5, 1.0, 1.5]))
        .cast::<F32>()
        .write()
        .into_pipeline();
    let structured = Chain::read_resize::<U8>(Rect::new(3, 2, 20, 14), 10, 6)
        .map(CvtColor)
        .cast::<F32>()
        .write_split()
        .into_pipeline();
    let reduce = Chain::read::<U8>(&[8, 9])
        .map(Mul(0.5))
        .reduce_per_channel(ReduceKind::Mean)
        .into_pipeline();
    let faulted = Chain::read::<U8>(&[8, 9]).map(Add(3.0)).cast::<F32>().write().into_pipeline();

    let mut rng = Rng::new(11);
    let p5 = chain5();
    let mut requests: Vec<(Pipeline, Tensor)> = Vec::new();
    for _ in 0..4 {
        requests.push((p5.clone(), dense_item(&mut rng)));
    }
    requests.push((mk_mul(2.0), dense_item(&mut rng)));
    requests.push((mk_mul(5.0), dense_item(&mut rng)));
    requests.push((lanes, Tensor::from_u8(&rng.vec_u8(36), &[1, 4, 3, 3])));
    requests.push((structured, make_frame(40, 50, 12)));
    requests.push((reduce, dense_item(&mut rng)));
    requests.push((faulted, dense_item(&mut rng)));

    let wall_t0 = Instant::now();
    let rxs: Vec<_> =
        requests.iter().map(|(p, t)| svc.submit(p.clone(), t.clone()).unwrap()).collect();
    let mut failures = 0;
    for (i, rx) in rxs.into_iter().enumerate() {
        let reply = rx.recv().expect("service alive");
        match reply {
            Ok(out) => {
                let (p, t) = &requests[i];
                assert_eq!(out, fkl::hostref::run_pipeline(p, t), "request {i}: bit-equal");
            }
            Err(e) => {
                assert_eq!(i, requests.len() - 1, "only the add stream may fail, got {e} at {i}");
                failures += 1;
            }
        }
    }
    let wall_us = wall_t0.elapsed().as_micros() as u64;
    assert_eq!(failures, 1, "the fault-injected request failed typed");
    svc.shutdown();

    let spans = tracer.spans();
    let trees = by_request(&spans);
    assert_eq!(trees.len(), requests.len(), "one span tree per submitted request");
    for (req, tree) in &trees {
        assert_tree_wellformed(*req, tree);
        let root = tree.iter().find(|s| s.id == 0).unwrap();
        assert!(
            root.dur_us <= wall_us + 2,
            "req {req}: root ({}us) exceeds the e2e envelope ({wall_us}us)",
            root.dur_us
        );
    }

    // tier coverage: the chain-5 company stacked 4-wide, and the divergent
    // remainder (param twin + structured + reduce) shared a pass
    let tiers: Vec<&SpanRecord> = spans.iter().filter(|s| s.stage == Stage::Tier).collect();
    assert!(
        tiers.iter().any(|s| s.a == TIER_STACKED && s.c >= 4),
        "chain-5 company must stack: {tiers:?}"
    );
    assert!(
        tiers.iter().any(|s| s.a == TIER_DIVERGENT && s.c >= 2),
        "the mixed remainder must share a divergent pass: {tiers:?}"
    );

    // the fault-injected request is COMPLETE (all sequential stages closed)
    // and carries its error on the span that failed — the launch that ran
    let failed_roots: Vec<&SpanRecord> =
        spans.iter().filter(|s| s.id == 0 && s.err.is_some()).collect();
    assert_eq!(failed_roots.len(), 1, "exactly one failing request: {failed_roots:?}");
    let failed_req = failed_roots[0].req;
    let failed_tree = &trees[&failed_req];
    let failing: Vec<&SpanRecord> =
        failed_tree.iter().filter(|s| s.id != 0 && s.err.is_some()).collect();
    assert!(
        failing.iter().all(|s| s.stage == Stage::Launch || s.stage == Stage::Tier),
        "the error lands on the stage that failed: {failing:?}"
    );
    assert!(!failing.is_empty(), "the failing stage is recorded: {failed_tree:?}");
    let reply = failed_tree.iter().find(|s| s.stage == Stage::Reply).unwrap();
    assert_eq!(reply.a, 0, "the failing request's reply records not-ok");

    // the capture round-trips through the in-crate parser as Chrome events
    let chrome = tracer.to_chrome_trace();
    let parsed = fkl::jsonlite::parse(&chrome.to_json()).expect("export parses back");
    assert_eq!(parsed, chrome, "lossless round-trip");
    let events = parsed["traceEvents"].as_arr().expect("traceEvents array");
    assert_eq!(events.len(), spans.len());
    for e in events {
        assert_eq!(e["ph"].as_str(), Some("X"), "complete events only");
        for key in ["name", "ts", "dur", "pid", "tid"] {
            assert!(e[key].as_f64().is_some() || e[key].as_str().is_some(), "missing {key}");
        }
        let tid = e["tid"].as_f64().unwrap() as u64;
        assert!(trees.contains_key(&tid), "tid {tid} names a traced request");
    }
}

#[test]
fn tracing_off_is_bit_identical_to_tracing_on() {
    // identical traffic through an armed and an unarmed service: the replies
    // and the byte accounting must not depend on whether anyone is watching
    let run = |tracing: Option<Arc<Tracer>>| {
        let svc = Service::start(ServiceConfig {
            artifact_dir: None,
            queue_cap: 64,
            policy: BatchPolicy { max_batch: 8, window: Duration::from_micros(200), ..Default::default() },
            engine: EngineSelect::HostFused,
            tracing,
            ..ServiceConfig::default()
        });
        let p = chain5();
        let mut rng = Rng::new(23);
        // submit->recv serially so both runs see identical windows (one
        // request each): the byte counters then compare exactly
        let mut outs: Vec<Tensor> = Vec::new();
        for _ in 0..10 {
            let rx = svc.submit(p.clone(), dense_item(&mut rng)).unwrap();
            outs.push(rx.recv().unwrap().expect("request ok"));
        }
        let m = svc.metrics().unwrap();
        svc.shutdown();
        (outs, m)
    };
    let tracer = Arc::new(Tracer::new());
    let (traced, mt) = run(Some(tracer.clone()));
    let (plain, mp) = run(None);
    assert!(tracer.span_count() > 0, "the armed tracer recorded the session");
    assert_eq!(traced, plain, "tracing must not change a single bit of output");
    assert_eq!(mt.completed, mp.completed);
    assert_eq!((mt.failed, mp.failed), (0, 0));
    // the byte model is per-item linear, so it is batching- and
    // tracing-invariant for identical traffic
    assert_eq!(mt.bytes_read, mp.bytes_read);
    assert_eq!(mt.bytes_written, mp.bytes_written);
    assert_eq!(mt.bytes_baseline, mp.bytes_baseline);
}

#[test]
fn fault_injected_stacked_launch_traces_the_error_on_the_launch_span() {
    // deterministic window boundaries (the fault_props idiom): max_batch 2
    // with a huge window pops exactly when both riders are queued
    let tracer = Arc::new(Tracer::new());
    let svc = Service::start(ServiceConfig {
        artifact_dir: None,
        queue_cap: 16,
        policy: BatchPolicy { max_batch: 2, window: Duration::from_secs(600), ..Default::default() },
        engine: EngineSelect::HostFused,
        faults: Some(FaultPlan::parse("sig=mul,tier=stacked,launch=0,action=err").unwrap()),
        tracing: Some(tracer.clone()),
        ..ServiceConfig::default()
    });
    let p = Chain::read::<U8>(&[4, 5]).map(Mul(2.0)).cast::<F32>().write().into_pipeline();
    let rxs: Vec<_> = (0..2u8)
        .map(|i| svc.submit(p.clone(), Tensor::from_u8(&[10 + i; 20], &[1, 4, 5])).unwrap())
        .collect();
    for rx in rxs {
        assert!(rx.recv().expect("service alive").is_err(), "launch 0 is fault-injected");
    }
    svc.shutdown();

    let trees = by_request(&tracer.spans());
    assert_eq!(trees.len(), 2, "both riders trace");
    for (req, tree) in &trees {
        assert_tree_wellformed(*req, tree);
        let root = tree.iter().find(|s| s.id == 0).unwrap();
        let launch = tree
            .iter()
            .find(|s| s.stage == Stage::Launch)
            .unwrap_or_else(|| panic!("req {req}: the failed launch is still a span: {tree:?}"));
        assert_eq!(launch.parent, 3, "the launch nests under the tier span");
        assert_eq!(launch.err, root.err, "the error lands on the span that failed");
        assert!(launch.err.is_some(), "req {req}: launch carries the typed error name");
        let tier = tree.iter().find(|s| s.stage == Stage::Tier).unwrap();
        assert_eq!(tier.err, None, "the launch, not the tier, is the failing stage");
        assert_eq!(tier.a, TIER_STACKED);
    }
}

#[test]
fn fusion_efficiency_reports_the_chain_k_ratio() {
    let serve_bytes = |p: &Pipeline| {
        let svc = Service::start(ServiceConfig {
            artifact_dir: None,
            queue_cap: 64,
            policy: BatchPolicy { max_batch: 8, window: Duration::from_micros(200), ..Default::default() },
            engine: EngineSelect::HostFused,
            ..ServiceConfig::default()
        });
        let mut rng = Rng::new(3);
        let rxs: Vec<_> =
            (0..8).map(|_| svc.submit(p.clone(), dense_item(&mut rng)).unwrap()).collect();
        for rx in rxs {
            rx.recv().unwrap().expect("request ok");
        }
        let m = svc.metrics().unwrap();
        svc.shutdown();
        m
    };
    // dense chain-5: op-at-a-time re-materializes 4 intermediates, so the
    // fused pass moves far fewer bytes — the paper's whole argument
    let m5 = serve_bytes(&chain5());
    assert!(m5.bytes_read > 0 && m5.bytes_written > 0, "byte accounting engaged: {m5:?}");
    assert!(
        m5.bytes_baseline > m5.bytes_read + m5.bytes_written,
        "chain-5 baseline must exceed the fused pass"
    );
    assert!(
        m5.fusion_efficiency() > 1.5,
        "chain-5 dense efficiency {} must clear 1.5x",
        m5.fusion_efficiency()
    );
    // chain-1 has no intermediates to save: efficiency is exactly 1.0
    let m1 = serve_bytes(&chain1());
    assert!(
        (m1.fusion_efficiency() - 1.0).abs() < 0.05,
        "chain-1 efficiency {} must be ~1.0",
        m1.fusion_efficiency()
    );
}

#[test]
fn metrics_snapshot_json_matches_the_counters() {
    let svc = Service::start(ServiceConfig {
        artifact_dir: None,
        queue_cap: 64,
        policy: BatchPolicy { max_batch: 8, window: Duration::from_micros(200), ..Default::default() },
        engine: EngineSelect::HostFused,
        ..ServiceConfig::default()
    });
    let p = chain5();
    let mut rng = Rng::new(5);
    let rxs: Vec<_> =
        (0..6).map(|_| svc.submit(p.clone(), dense_item(&mut rng)).unwrap()).collect();
    for rx in rxs {
        rx.recv().unwrap().expect("request ok");
    }
    let m = svc.metrics().unwrap();
    svc.shutdown();

    let j = m.to_json();
    let n = |v: &fkl::jsonlite::Value| v.as_f64().expect("numeric field");
    assert_eq!(n(&j["completed"]), m.completed as f64);
    assert_eq!(n(&j["launches"]), m.launches as f64);
    assert_eq!(n(&j["bytes_read"]), m.bytes_read as f64);
    assert_eq!(n(&j["bytes_written"]), m.bytes_written as f64);
    assert_eq!(n(&j["bytes_baseline"]), m.bytes_baseline as f64);
    assert_eq!(n(&j["fusion_efficiency"]), m.fusion_efficiency());
    assert_eq!(n(&j["tier_time_us"]["stacked"]), m.tier_time_us.stacked as f64);
    assert_eq!(n(&j["tier_time_us"]["plan"]), m.tier_time_us.plan as f64);
    assert_eq!(n(&j["latency_us"]["count"]), m.latency.count as f64);
    assert_eq!(n(&j["latency_us"]["p999"]), m.latency.p999 as f64);
    // and the dump survives its own serialization
    let parsed = fkl::jsonlite::parse(&j.to_json()).expect("snapshot JSON parses");
    assert_eq!(parsed, j, "lossless round-trip");
    assert_eq!(n(&parsed["completed"]), m.completed as f64);
}
