//! Property tests for the STRUCTURED boundaries of the host fused engine:
//! crop reads, bilinear crop+resize reads and split writes, randomized over
//! geometry and dtypes — pure host code, runs everywhere.
//!
//! Contract being enforced (the structured half of the numerics story):
//! * every structured pass accumulates in f64 and is BIT-equal to the
//!   structured `hostref::run_pipeline` oracle;
//! * an identity resize (dst size == rect size) reproduces the crop
//!   bitwise — the taps hit whole pixels with zero fractional weight;
//! * 1×1 rects broadcast their single source pixel to every output pixel;
//! * edge-touching rects clamp exactly like the oracle;
//! * split-write output is the exact packed→planar permutation of the
//!   dense-write output, for all five dtypes.

use fkl::chain::{Chain, F32 as CF32, U8 as CU8};
use fkl::exec::{Engine, HostFusedEngine};
use fkl::hostref;
use fkl::ops::{IOp, MemOp, Opcode, Pipeline};
use fkl::proplite::{forall, Rng};
use fkl::tensor::{crop_frame, make_frame, DType, Rect, Tensor};

const DTYPES: [DType; 5] = [DType::U8, DType::U16, DType::I32, DType::F32, DType::F64];

/// Random in-bounds rect within an `fh`×`fw` frame (full-frame included).
fn rand_rect(rng: &mut Rng, fh: usize, fw: usize) -> Rect {
    let w = rng.usize(1, fw + 1) as i32;
    let h = rng.usize(1, fh + 1) as i32;
    let x0 = rng.usize(0, (fw as i32 - w) as usize + 1) as i32;
    let y0 = rng.usize(0, (fh as i32 - h) as usize + 1) as i32;
    Rect::new(x0, y0, w, h)
}

#[test]
fn prop_identity_resize_reproduces_the_crop_bitwise() {
    forall(120, |rng| {
        let eng = HostFusedEngine::with_threads(rng.usize(1, 4));
        let (fh, fw) = (rng.usize(4, 40), rng.usize(4, 40));
        let frame = make_frame(fh, fw, rng.next_u64());
        let r = rand_rect(rng, fh, fw);
        let (h, w) = (r.h as usize, r.w as usize);

        let crop = Chain::read_crop::<CU8>(r).write().into_pipeline();
        let resize = Chain::read_resize::<CU8>(r, h, w).write().into_pipeline();
        let via_crop = eng.run(&crop, &frame).unwrap();
        let via_resize = eng.run(&resize, &frame).unwrap();
        assert_eq!(via_crop, via_resize, "identity resize == crop for {r:?}");
        // and both equal the strict crop oracle
        let want = crop_frame(&frame, r);
        assert_eq!(via_crop.as_u8().unwrap(), want.as_u8().unwrap(), "{r:?}");
    });
}

#[test]
fn prop_1x1_rects_broadcast_their_pixel() {
    forall(80, |rng| {
        let eng = HostFusedEngine::with_threads(1);
        let (fh, fw) = (rng.usize(2, 24), rng.usize(2, 24));
        let frame = make_frame(fh, fw, rng.next_u64());
        let x0 = rng.usize(0, fw) as i32;
        let y0 = rng.usize(0, fh) as i32;
        let r = Rect::new(x0, y0, 1, 1);
        let (dh, dw) = (rng.usize(1, 9), rng.usize(1, 9));
        let p = Chain::read_resize::<CU8>(r, dh, dw).write().into_pipeline();
        let out = eng.run(&p, &frame).unwrap();
        let src = frame.as_u8().unwrap();
        let px = &src[((y0 as usize) * fw + x0 as usize) * 3..][..3];
        for pixel in out.as_u8().unwrap().chunks(3) {
            assert_eq!(pixel, px, "1x1 rect at ({x0},{y0}) scaled to {dh}x{dw}");
        }
    });
}

#[test]
fn prop_odd_even_resizes_match_the_oracle_bitwise() {
    // odd<->even size changes exercise every fractional-tap shape; the
    // engine gathers in f64 through the shared tap table, so the bilinear
    // oracle must be reproduced BITWISE (f32 out = same final rounding)
    forall(120, |rng| {
        let eng = HostFusedEngine::with_threads(rng.usize(1, 4));
        let (fh, fw) = (rng.usize(6, 48), rng.usize(6, 48));
        let frame = make_frame(fh, fw, rng.next_u64());
        let r = rand_rect(rng, fh, fw);
        let (dh, dw) = (rng.usize(1, 33), rng.usize(1, 33));
        let p = Chain::read_resize::<CU8>(r, dh, dw)
            .cast::<CF32>()
            .write()
            .into_pipeline();
        let got = eng.run(&p, &frame).unwrap();
        assert_eq!(got.shape(), &[1, dh, dw, 3]);
        let want = hostref::bilinear_crop_resize(&frame, r, dh, dw);
        assert_eq!(got.as_f32().unwrap(), want.as_f32().unwrap(), "{r:?} -> {dh}x{dw}");
        // and the structured pipeline oracle agrees with both
        assert_eq!(got, hostref::run_pipeline(&p, &frame));
    });
}

#[test]
fn prop_edge_rects_clamp_like_the_oracle() {
    // rects pinned to the frame borders: the (dy+0.5)*scale-0.5 half-pixel
    // mapping samples past the rect edge there, so the clamp rule is load-
    // bearing — engine and oracle must agree bitwise
    forall(100, |rng| {
        let eng = HostFusedEngine::with_threads(1);
        let (fh, fw) = (rng.usize(4, 32), rng.usize(4, 32));
        let frame = make_frame(fh, fw, rng.next_u64());
        let w = rng.usize(1, fw + 1) as i32;
        let h = rng.usize(1, fh + 1) as i32;
        // pin to one of the four corners so the rect touches two edges
        let (x0, y0) = match rng.usize(0, 4) {
            0 => (0, 0),
            1 => (fw as i32 - w, 0),
            2 => (0, fh as i32 - h),
            _ => (fw as i32 - w, fh as i32 - h),
        };
        let r = Rect::new(x0, y0, w, h);
        let (dh, dw) = (rng.usize(1, 17), rng.usize(1, 17));
        let p = Chain::read_resize::<CU8>(r, dh, dw)
            .cast::<CF32>()
            .write()
            .into_pipeline();
        let got = eng.run(&p, &frame).unwrap();
        let want = hostref::bilinear_crop_resize(&frame, r, dh, dw);
        assert_eq!(got.as_f32().unwrap(), want.as_f32().unwrap(), "{r:?} in {fh}x{fw}");
    });
}

#[test]
fn prop_split_write_is_the_exact_pack_permutation_all_dtypes() {
    // dense-read chains, written packed vs split: the planar output must be
    // the exact packed->planar permutation of the packed output (and
    // re-packing it roundtrips), for every dtype pair's boundary semantics
    forall(200, |rng| {
        let eng = HostFusedEngine::with_threads(rng.usize(1, 4));
        let dtin = *rng.pick(&DTYPES);
        let dtout = *rng.pick(&DTYPES);
        let (h, w) = (rng.usize(1, 9), rng.usize(1, 9));
        let batch = rng.usize(1, 4);
        let k = rng.usize(1, 5);
        let body: Vec<IOp> = (0..k)
            .map(|_| {
                let op = *rng.pick(&[Opcode::Mul, Opcode::Add, Opcode::Sub, Opcode::Max]);
                IOp::compute(op, rng.f64(0.5, 1.5))
            })
            .collect();
        let mk = |write: MemOp| {
            let mut ops = vec![IOp::Mem(MemOp::Read { dtype: dtin })];
            ops.extend(body.iter().cloned());
            ops.push(IOp::Mem(write));
            Pipeline::new(ops, vec![h, w, 3], batch, dtin, dtout).unwrap()
        };
        let packed_p = mk(MemOp::Write { dtype: dtout });
        let split_p = mk(MemOp::SplitWrite { dtype: dtout });

        let n = batch * h * w * 3;
        let vals: Vec<f64> = (0..n).map(|_| rng.f64(0.0, 200.0)).collect();
        let x = Tensor::from_f64_cast(&vals, &[batch, h, w, 3], dtin);

        let split = eng.run(&split_p, &x).unwrap();
        assert_eq!(split.shape(), split_p.out_shape().as_slice());
        assert_eq!(split, hostref::run_pipeline(&split_p, &x), "oracle bit-equal");

        // permute the f64-path packed result and compare raw views
        // (bit-exact: the split pass folds in f64 like the dense oracle and
        // both sides take the same per-element write boundary)
        let pv = hostref::run_pipeline(&packed_p, &x).to_f64_vec();
        let sv = split.to_f64_vec();
        let pixels = h * w;
        for b in 0..batch {
            for i in 0..pixels {
                for c in 0..3 {
                    let from = b * pixels * 3 + i * 3 + c;
                    let to = b * pixels * 3 + c * pixels + i;
                    assert!(
                        pv[from] == sv[to] || (pv[from].is_nan() && sv[to].is_nan()),
                        "{dtin}->{dtout} b={b} px={i} c={c}: {} vs {}",
                        pv[from],
                        sv[to]
                    );
                }
            }
        }
    });
}
