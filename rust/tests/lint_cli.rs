//! `fkl lint` exit-code contract, exercised against the real binary:
//!
//! * warnings/infos only -> exit 0 (lint output on stdout);
//! * at least one error-severity diagnostic -> exit 1;
//! * malformed chain spec -> exit 2 with a TYPED parse error on stderr —
//!   never a panic (the lint front door takes arbitrary user input).

use std::process::{Command, Output};

fn fkl(args: &[&str]) -> Output {
    Command::new(env!("CARGO_BIN_EXE_fkl")).args(args).output().expect("spawn fkl")
}

#[test]
fn warn_only_chains_exit_zero() {
    let out = fkl(&[
        "lint", "--ops", "mul:1.0,add:0.5", "--shape", "8x8", "--dtin", "u8", "--dtout", "f32",
    ]);
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert_eq!(out.status.code(), Some(0), "warn-only lint must exit 0: {stdout}");
    assert!(stdout.contains("FKL001"), "identity op diagnosed: {stdout}");
    assert!(stdout.contains("FKL008"), "tier prediction always present: {stdout}");
    assert!(!stdout.contains("error["), "no error-severity diagnostics: {stdout}");
}

#[test]
fn error_diagnostics_exit_one() {
    // div by literal zero is FKL007, the analyzer's only Error severity
    let out = fkl(&["lint", "--ops", "div:0.0", "--shape", "4x4"]);
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert_eq!(out.status.code(), Some(1), "error diagnostics must exit 1: {stdout}");
    assert!(stdout.contains("error[FKL007]"), "{stdout}");
}

#[test]
fn malformed_specs_exit_two_with_a_typed_error_not_a_panic() {
    for (args, needle) in [
        (vec!["lint", "--ops", "frobnicate", "--shape", "4x4"], "unknown op"),
        (vec!["lint", "--ops", "mul:abc", "--shape", "4x4"], "malformed parameter"),
        (vec!["lint", "--ops", "mul", "--shape", "4x4", "--dtin", "u9"], "unknown dtype"),
        (vec!["lint", "--ops", "mul", "--shape", "4yy"], "malformed shape"),
        (vec!["lint", "--shape", "4x4"], "empty"),
        (vec!["lint", "--ops", "cast:bogus", "--shape", "4x4"], "unknown dtype"),
    ] {
        let out = fkl(&args);
        let stderr = String::from_utf8_lossy(&out.stderr);
        assert_eq!(out.status.code(), Some(2), "{args:?} must exit 2: {stderr}");
        assert!(stderr.contains(needle), "{args:?}: typed error expected, got: {stderr}");
        assert!(!stderr.contains("panicked"), "{args:?}: panicked instead of typed: {stderr}");
    }
}

#[test]
fn json_report_is_machine_readable() {
    let out = fkl(&[
        "lint", "--ops", "mul:1.0,neg,neg,add:2.0", "--shape", "8x8", "--json",
    ]);
    assert_eq!(out.status.code(), Some(0));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("\"diagnostics\""), "{stdout}");
    assert!(stdout.contains("\"rewrites_applied\""), "{stdout}");
    assert!(stdout.contains("\"FKL001\""), "{stdout}");
}
