//! Differential fuzz harness — random fused chains vs the hostref oracle.
//!
//! A seeded `proplite`-driven generator builds random pipelines over the
//! WHOLE vocabulary — all 5×5 dtype pairs, op chains 1..=12 (scalar,
//! per-channel C3 and CvtColor stages), dense / crop / crop+resize reads,
//! dense / split writes and reduce seals — and executes every case on the
//! host fused engine at 1, 2 and 8 worker threads against the
//! materializing oracle.
//!
//! Comparison contract (the engine's documented numerics):
//! * every f64-accumulated plan — integer outputs, f64/i32 inputs,
//!   lane-structured bodies, ALL structured boundaries, ALL reductions —
//!   must be BIT-EQUAL to the oracle;
//! * the f32 fast arm (dense all-scalar chain, f32 out, u8/u16/f32 in) is
//!   epsilon-close to the oracle's f64 sweep (the generator keeps its value
//!   magnitudes bounded so the epsilon is meaningful);
//! * thread count must NEVER change a result, bitwise, on any path.
//!
//! Seeds are FIXED and committed, so a failure reproduces exactly: the
//! panic message names the seed, the case index and the signature.
//!
//! The harness is also the canonicalizer's bit-safety proof
//! ([`fkl::analysis::canonicalize`]): the generator sprinkles removable
//! identity stages into its cases, and every case additionally checks that
//! the canonical twin serves BIT-EQUAL to the raw pipeline on every
//! f64-accumulated path and every thread count (the f32 fast arm reuses the
//! oracle epsilon), that canonicalization is idempotent, and that it never
//! touches the reduce seal or the read/write patterns.

use fkl::analysis;
use fkl::exec::{Engine, HostFusedEngine};
use fkl::fusion::{HostAccum, HostPlan};
use fkl::hostref;
use fkl::ops::{
    IOp, MemOp, Opcode, Pipeline, ReduceAxis, ReduceSpec, Signature, ALL_OPCODES,
    ALL_REDUCE_KINDS,
};
use fkl::proplite::Rng;
use fkl::tensor::{DType, Rect, Tensor};

/// The committed seed set: every run fuzzes exactly these cases.
const SEEDS: [u64; 6] = [1, 2, 3, 0xF5ED, 0xBEEF, 20260728];
const CASES_PER_SEED: usize = 25;

const ALL_DTYPES: [DType; 5] = [DType::U8, DType::U16, DType::I32, DType::F32, DType::F64];

/// Scalar opcodes for the f32 fast arm: everything but `Exp` — a random
/// exp tower overflows f32 long before f64, which would turn the epsilon
/// comparison into inf-vs-finite. f64-accumulated plans fuzz the full set
/// (overflow propagates identically on both sides there).
const NARROW_OPS: [Opcode; 12] = [
    Opcode::Nop,
    Opcode::Add,
    Opcode::Sub,
    Opcode::Mul,
    Opcode::Div,
    Opcode::Abs,
    Opcode::Neg,
    Opcode::Min,
    Opcode::Max,
    Opcode::Sqrt,
    Opcode::Log,
    Opcode::Clamp01,
];

struct Case {
    pipeline: Pipeline,
    input: Tensor,
    /// True when the generator expects the f32 fast arm (checked against
    /// the compiled plan, compared by epsilon instead of bits).
    narrow: bool,
}

/// Scalar param in the full f64 domain: `Div` stays away from 0 so value
/// magnitudes don't explode past what the EPSILON paths can absorb (the
/// bitwise paths would survive it, but the generator is shared).
fn scalar_param(rng: &mut Rng, op: Opcode, narrow: bool) -> f64 {
    match op {
        Opcode::Div => {
            let lo = if narrow { 0.8 } else { 0.25 };
            let mag = rng.f64(lo, 3.0);
            if rng.bool() {
                mag
            } else {
                -mag
            }
        }
        // the narrow arm also bounds multiplicative growth: 1.25^12 stays
        // representable in f32 with room for the additive terms
        Opcode::Mul if narrow => rng.f64(-1.25, 1.25),
        _ => rng.f64(-3.0, 3.0),
    }
}

fn c3_param(rng: &mut Rng, op: Opcode) -> [f32; 3] {
    [
        scalar_param(rng, op, false) as f32,
        scalar_param(rng, op, false) as f32,
        scalar_param(rng, op, false) as f32,
    ]
}

/// Random tensor with values natural to the dtype (image bytes, small
/// signed floats, ...). `from_f64_cast` rounds and saturates exactly like
/// the kernels' write boundary.
fn random_tensor(rng: &mut Rng, dtype: DType, shape: &[usize]) -> Tensor {
    let n: usize = shape.iter().product();
    let vals: Vec<f64> = (0..n)
        .map(|_| match dtype {
            DType::U8 => rng.f64(0.0, 256.0),
            DType::U16 => rng.f64(0.0, 1024.0),
            DType::I32 => rng.f64(-512.0, 512.0),
            DType::F32 | DType::F64 => rng.f64(-4.0, 4.0),
        })
        .collect();
    Tensor::from_f64_cast(&vals, shape, dtype)
}

/// One random case. `force_dtin` / `force_term` pin the generator for the
/// directed dtype×terminator sweep; `None` samples freely.
fn gen_case(rng: &mut Rng, force_dtin: Option<DType>, force_term: Option<usize>) -> Case {
    let dtin = force_dtin.unwrap_or_else(|| *rng.pick(&ALL_DTYPES));
    let batch = rng.usize(1, 4);

    // read end: dense over a random shape (pixel-shaped half the time so
    // split writes and C3 bodies get dense coverage), or a crop-family
    // gather from a shared frame
    let read_kind = rng.usize(0, 5); // 0..=2 dense, 3 crop, 4 resize
    let (read, shape, input) = if read_kind <= 2 {
        let shape = if rng.bool() {
            vec![rng.usize(1, 7), rng.usize(1, 7), 3]
        } else {
            vec![rng.usize(1, 10), rng.usize(1, 10)]
        };
        let mut full = vec![batch];
        full.extend_from_slice(&shape);
        let input = random_tensor(rng, dtin, &full);
        (MemOp::Read { dtype: dtin }, shape, input)
    } else {
        let (fh, fw) = (rng.usize(6, 20), rng.usize(6, 20));
        // rects may hang over the frame edge: samples clamp, like the oracle
        let rect = Rect::new(
            rng.usize(0, fw) as i32,
            rng.usize(0, fh) as i32,
            rng.usize(1, 9) as i32,
            rng.usize(1, 9) as i32,
        );
        let input = random_tensor(rng, dtin, &[fh, fw, 3]);
        if read_kind == 3 {
            let shape = vec![rect.h as usize, rect.w as usize, 3];
            (MemOp::CropRead { rect }, shape, input)
        } else {
            let (dh, dw) = (rng.usize(1, 9), rng.usize(1, 9));
            (MemOp::ResizeRead { rect, dst_h: dh, dst_w: dw }, vec![dh, dw, 3], input)
        }
    };
    let pixel = shape.len() == 3 && shape[2] == 3;
    let structured_read = read_kind > 2;

    // terminator: dense write / split write (pixel shapes only) / reduce
    let term_kind = force_term.unwrap_or_else(|| rng.usize(0, 4)); // 0..=1 write, 2 split, 3 reduce
    let (term, dtout) = if term_kind == 3 {
        let kind = *rng.pick(&ALL_REDUCE_KINDS);
        let axis = if rng.bool() { ReduceAxis::Full } else { ReduceAxis::PerChannel };
        let spec = if rng.bool() {
            ReduceSpec::single(kind, axis)
        } else {
            ReduceSpec::pair(kind, *rng.pick(&ALL_REDUCE_KINDS), axis)
        };
        (MemOp::Reduce { spec }, DType::F64)
    } else {
        let dtout = *rng.pick(&ALL_DTYPES);
        if term_kind == 2 && pixel {
            (MemOp::SplitWrite { dtype: dtout }, dtout)
        } else {
            (MemOp::Write { dtype: dtout }, dtout)
        }
    };
    let dense_write = matches!(term, MemOp::Write { .. });

    // body: lane-structured stages force the f64 group path; otherwise the
    // case may land on the narrow f32 arm, whose op/param pool is bounded
    let use_group_ops = rng.usize(0, 3) == 0;
    let narrow = !use_group_ops
        && !structured_read
        && dense_write
        && dtout == DType::F32
        && matches!(dtin, DType::U8 | DType::U16 | DType::F32);
    let k = rng.usize(1, 13);
    let mut ops: Vec<IOp> = Vec::with_capacity(k + 2);
    ops.push(IOp::Mem(read));
    for i in 0..k {
        if use_group_ops && (i == 0 || rng.usize(0, 3) == 0) {
            // guarantee at least one lane-structured stage up front
            if rng.bool() {
                ops.push(IOp::CvtColor);
            } else {
                let op = *rng.pick(&[Opcode::Add, Opcode::Sub, Opcode::Mul, Opcode::Div]);
                ops.push(IOp::ComputeC3 { op, param: c3_param(rng, op) });
            }
        } else {
            let pool: &[Opcode] = if narrow { &NARROW_OPS } else { &ALL_OPCODES };
            let op = *rng.pick(pool);
            ops.push(IOp::compute(op, scalar_param(rng, op, narrow)));
        }
    }
    // canonicalizer fodder: a third of the cases get bit-exact identity
    // stages (and inverse pairs) spliced into the body, so the
    // raw-vs-canonicalized contract below fuzzes REAL removals — the noise
    // is semantically free, so the narrow prediction is untouched
    if rng.usize(0, 3) == 0 {
        for _ in 0..rng.usize(1, 4) {
            let at = rng.usize(1, ops.len() + 1); // body slots, after the read
            match rng.usize(0, 5) {
                0 => ops.insert(at, IOp::compute(Opcode::Mul, 1.0)),
                1 => ops.insert(at, IOp::compute(Opcode::Div, 1.0)),
                2 => ops.insert(at, IOp::compute(Opcode::Sub, 0.0)),
                3 => ops.insert(at, IOp::compute(Opcode::Nop, 0.0)),
                _ => {
                    ops.insert(at, IOp::compute(Opcode::Neg, 0.0));
                    ops.insert(at, IOp::compute(Opcode::Neg, 0.0));
                }
            }
        }
    }
    ops.push(IOp::Mem(term));

    let pipeline = Pipeline::new(ops, shape, batch, dtin, dtout)
        .expect("generated chains are valid by construction");
    Case { pipeline, input, narrow }
}

/// Bitwise tensor comparison through the (lossless) f64 view — `PartialEq`
/// would reject NaN==NaN, but two runs that produce the same bits must
/// count as equal.
fn assert_bits_eq(got: &Tensor, want: &Tensor, ctx: &str) {
    assert_eq!(got.shape(), want.shape(), "{ctx}: shape");
    assert_eq!(got.dtype(), want.dtype(), "{ctx}: dtype");
    let (g, w) = (got.to_f64_vec(), want.to_f64_vec());
    for (i, (a, b)) in g.iter().zip(&w).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{ctx}: elem {i}: {a} vs {b}");
    }
}

/// Returns the number of bit-safe rewrites the canonicalizer applied to
/// this case (the fuzz corpus asserts it exercised real removals overall).
fn check_case(case: &Case, engines: &[HostFusedEngine; 3], ctx: &str) -> usize {
    let p = &case.pipeline;
    let plan = HostPlan::compile(p);
    // the generator's narrow prediction must match the planner: a drift
    // here would silently fuzz the wrong comparison contract
    assert_eq!(
        plan.accum() == HostAccum::F32,
        case.narrow,
        "{ctx}: accumulator prediction drifted"
    );
    let want = hostref::run_pipeline(p, &case.input);
    let outs: Vec<Tensor> = engines
        .iter()
        .map(|eng| eng.run(p, &case.input).expect("generated case must serve"))
        .collect();
    // thread count never changes results, bitwise, on ANY path
    assert_bits_eq(&outs[1], &outs[0], &format!("{ctx}: threads 2 vs 1"));
    assert_bits_eq(&outs[2], &outs[0], &format!("{ctx}: threads 8 vs 1"));
    if case.narrow {
        // the f32 fast arm: epsilon vs the oracle's f64 sweep; magnitudes
        // are generator-bounded (~2e4), so the absolute term dominates the
        // worst cancellation case
        assert_eq!(outs[0].shape(), want.shape(), "{ctx}");
        let (g, w) = (outs[0].to_f64_vec(), want.to_f64_vec());
        for (i, (a, b)) in g.iter().zip(&w).enumerate() {
            // NaN should be unreachable here (Sqrt/Log are |x|-guarded and
            // the narrow generator bounds magnitudes), but if both sides
            // agree on NaN that is agreement, not an epsilon failure
            if a.is_nan() && b.is_nan() {
                continue;
            }
            assert!(
                (a - b).abs() <= 0.05 + 1e-4 * b.abs(),
                "{ctx}: f32 arm elem {i}: {a} vs {b}"
            );
        }
    } else {
        assert_bits_eq(&outs[0], &want, &format!("{ctx}: vs oracle"));
    }

    // scalar-vs-vectorized differential: the same case served under the
    // engine's width-1 override (the pre-SIMD loops) across thread counts.
    // Register blocking must be invisible — bit-for-bit on every
    // f64-accumulated path (same per-element op sequence, data-addressed
    // reduce stripes); the f32 fast arm is held to the oracle epsilon
    assert!(plan.vectorization() > 1, "{ctx}: compiled plans always record a blocked width");
    for threads in [1usize, 2, 8] {
        let scalar_eng = HostFusedEngine::with_threads(threads).with_lane_width(1);
        let got = scalar_eng.run(p, &case.input).expect("scalar arm must serve");
        if case.narrow {
            assert_eq!(got.shape(), outs[0].shape(), "{ctx}: scalar-arm shape");
            let (g, w) = (got.to_f64_vec(), outs[0].to_f64_vec());
            for (i, (a, b)) in g.iter().zip(&w).enumerate() {
                if a.is_nan() && b.is_nan() {
                    continue;
                }
                assert!(
                    (a - b).abs() <= 0.05 + 1e-4 * b.abs(),
                    "{ctx}: scalar vs vector f32 arm elem {i}: {a} vs {b}"
                );
            }
        } else {
            let sctx = format!("{ctx}: scalar arm t{threads} vs vector");
            assert_bits_eq(&got, &outs[0], &sctx);
        }
        assert_eq!(scalar_eng.vector_runs(), 0, "{ctx}: the width-1 arm is not a vector run");
    }

    // raw-vs-canonicalized: only bit-safety-proven rewrites apply, so the
    // canonical twin must serve BIT-EQUAL on every f64-accumulated path and
    // every thread count; the f32 fast arm reuses the oracle epsilon
    let (canon, rewrites) = analysis::canonicalize(p.clone());
    let (canon2, again) = analysis::canonicalize(canon.clone());
    assert_eq!(canon2, canon, "{ctx}: canonicalize is idempotent");
    assert!(again.iter().all(|r| !r.applied), "{ctx}: idempotent pass re-applied rewrites");
    assert_eq!(canon.reduction(), p.reduction(), "{ctx}: canon kept the reduce seal");
    assert_eq!(canon.read_pattern(), p.read_pattern(), "{ctx}: canon kept the read pattern");
    assert_eq!(canon.write_pattern(), p.write_pattern(), "{ctx}: canon kept the write pattern");
    for (eng, raw_out) in engines.iter().zip(&outs) {
        let got = eng.run(&canon, &case.input).expect("canonical twin must serve");
        if case.narrow {
            assert_eq!(got.shape(), raw_out.shape(), "{ctx}: canonical shape");
            let (g, w) = (got.to_f64_vec(), raw_out.to_f64_vec());
            for (i, (a, b)) in g.iter().zip(&w).enumerate() {
                if a.is_nan() && b.is_nan() {
                    continue;
                }
                assert!(
                    (a - b).abs() <= 0.05 + 1e-4 * b.abs(),
                    "{ctx}: canonical f32 arm elem {i}: {a} vs {b}"
                );
            }
        } else {
            assert_bits_eq(&got, raw_out, &format!("{ctx}: canonical vs raw"));
        }
    }
    rewrites.iter().filter(|r| r.applied).count()
}

#[test]
fn differential_fuzz_random_chains_vs_oracle() {
    let engines = [
        HostFusedEngine::with_threads(1),
        HostFusedEngine::with_threads(2),
        HostFusedEngine::with_threads(8),
    ];
    let mut rewrites_applied = 0;
    for &seed in &SEEDS {
        let mut rng = Rng::new(seed);
        for case_i in 0..CASES_PER_SEED {
            let case = gen_case(&mut rng, None, None);
            let ctx = format!("seed {seed} case {case_i} sig {}", Signature::of(&case.pipeline));
            rewrites_applied += check_case(&case, &engines, &ctx);
        }
    }
    // the corpus must EXERCISE the canonicalizer, not vacuously pass it
    assert!(rewrites_applied > 0, "fuzz corpus never fired a canonicalizer rewrite");
    // and every production engine run took a register-blocked arm
    for eng in &engines {
        assert_eq!(eng.vector_runs(), eng.runs(), "every production run is vectorized");
        assert!(eng.vector_width() >= 8, "f64 blocks are at least 8 wide");
    }
}

#[test]
fn directed_lane_width_edges() {
    use fkl::ops::kernel::{LANE_WIDTH_F32, LANE_WIDTH_F64, REDUCE_LANES};
    use fkl::ops::ReduceKind;
    // buffer sizes that pin the blocked loops' edge behavior: one element
    // below/at/above each register-block width (the tail is the whole
    // buffer, empty, or a single element), sub-block buffers smaller than
    // any block, and block-multiple ±1 sizes for the 24-lane group arm —
    // every size through the full scalar-vs-vector check_case contract
    let engines = [
        HostFusedEngine::with_threads(1),
        HostFusedEngine::with_threads(2),
        HostFusedEngine::with_threads(8),
    ];
    let mut rng = Rng::new(0x51D3);
    let mut sizes: Vec<usize> = vec![1, 2, 3];
    for w in [LANE_WIDTH_F64, LANE_WIDTH_F32, REDUCE_LANES * 3] {
        sizes.extend_from_slice(&[w - 1, w, w + 1]);
    }
    for &n in &sizes {
        // f64 dense chain: the bitwise leg at every edge size
        let chain = Pipeline::from_opcodes(
            &[(Opcode::Mul, 1.7), (Opcode::Add, -0.3), (Opcode::Abs, 0.0)],
            &[n],
            1,
            DType::F64,
            DType::F64,
        )
        .unwrap();
        let input = random_tensor(&mut rng, DType::F64, &[1, n]);
        let ctx = format!("lane-edge f64 chain n={n}");
        check_case(&Case { pipeline: chain, input, narrow: false }, &engines, &ctx);

        // f32 fast arm (16-wide blocks): the epsilon leg
        let chain32 = Pipeline::from_opcodes(
            &[(Opcode::Mul, 1.1), (Opcode::Add, -0.3), (Opcode::Abs, 0.0)],
            &[n],
            1,
            DType::F32,
            DType::F32,
        )
        .unwrap();
        let input = random_tensor(&mut rng, DType::F32, &[1, n]);
        let ctx = format!("lane-edge f32 chain n={n}");
        check_case(&Case { pipeline: chain32, input, narrow: true }, &engines, &ctx);

        // full-axis pair reduce: sub-block sizes keep the stripe fast path
        // tail-only; sizes at/above REDUCE_LANES engage it with a tail of
        // n % REDUCE_LANES elements
        let reduce = Pipeline::new(
            vec![
                IOp::Mem(MemOp::Read { dtype: DType::F64 }),
                IOp::compute(Opcode::Mul, 1.000001),
                IOp::Mem(MemOp::Reduce {
                    spec: ReduceSpec::pair(ReduceKind::Mean, ReduceKind::SumSq, ReduceAxis::Full),
                }),
            ],
            vec![n],
            1,
            DType::F64,
            DType::F64,
        )
        .unwrap();
        let input = random_tensor(&mut rng, DType::F64, &[1, n]);
        let ctx = format!("lane-edge reduce n={n}");
        check_case(&Case { pipeline: reduce, input, narrow: false }, &engines, &ctx);
    }

    // lane-group bodies block 8 PIXELS (24 f64 lanes): pixel counts one
    // below/at/above the block width
    for px in [LANE_WIDTH_F64 - 1, LANE_WIDTH_F64, LANE_WIDTH_F64 + 1] {
        let ops = vec![
            IOp::Mem(MemOp::Read { dtype: DType::F32 }),
            IOp::CvtColor,
            IOp::ComputeC3 { op: Opcode::Mul, param: [0.5, -1.25, 2.0] },
            IOp::compute(Opcode::Add, 0.25),
            IOp::Mem(MemOp::Write { dtype: DType::F64 }),
        ];
        let p = Pipeline::new(ops, vec![1, px, 3], 1, DType::F32, DType::F64).unwrap();
        let input = random_tensor(&mut rng, DType::F32, &[1, 1, px, 3]);
        let ctx = format!("lane-edge group px={px}");
        check_case(&Case { pipeline: p, input, narrow: false }, &engines, &ctx);
    }
}

#[test]
fn directed_fuzz_covers_every_dtype_and_terminator() {
    // the acceptance sweep: every input dtype × {dense write, split write,
    // reduce seal} is exercised deterministically, not just by sampling
    let engines = [
        HostFusedEngine::with_threads(1),
        HostFusedEngine::with_threads(2),
        HostFusedEngine::with_threads(8),
    ];
    for &dtin in &ALL_DTYPES {
        for term in [0usize, 2, 3] {
            let mut rng = Rng::new(0xD17 + term as u64);
            for case_i in 0..6 {
                let case = gen_case(&mut rng, Some(dtin), Some(term));
                let ctx = format!(
                    "dtin {dtin} term {term} case {case_i} sig {}",
                    Signature::of(&case.pipeline)
                );
                check_case(&case, &engines, &ctx);
            }
        }
    }
}

#[test]
fn fuzz_at_threading_scale() {
    // the random cases stay small (debug-mode runtime); these two directed
    // cases cross MIN_ELEMS_PER_THREAD so 2/8 workers genuinely engage —
    // chunk boundaries and the blocked reduce tree under the same contract
    let engines = [
        HostFusedEngine::with_threads(1),
        HostFusedEngine::with_threads(2),
        HostFusedEngine::with_threads(8),
    ];
    let mut rng = Rng::new(0x5CA1E);
    let chain = Pipeline::from_opcodes(
        &[(Opcode::Mul, 1.001), (Opcode::Add, 0.01), (Opcode::Sqrt, 0.0)],
        &[200, 121], // odd width: ragged chunk boundaries
        3,
        DType::F32,
        DType::F32,
    )
    .unwrap();
    let input = random_tensor(&mut rng, DType::F32, &[3, 200, 121]);
    check_case(
        &Case { pipeline: chain, input, narrow: true },
        &engines,
        "threading-scale f32 chain",
    );

    let n = fkl::ops::kernel::REDUCE_BLOCK * 2 + 7; // straddles block edges
    let reduce = Pipeline::new(
        vec![
            IOp::Mem(MemOp::Read { dtype: DType::F64 }),
            IOp::compute(Opcode::Mul, 1.000001),
            IOp::Mem(MemOp::Reduce {
                spec: ReduceSpec::pair(
                    fkl::ops::ReduceKind::Mean,
                    fkl::ops::ReduceKind::SumSq,
                    ReduceAxis::Full,
                ),
            }),
        ],
        vec![n],
        1,
        DType::F64,
        DType::F64,
    )
    .unwrap();
    let input = random_tensor(&mut rng, DType::F64, &[1, n]);
    check_case(
        &Case { pipeline: reduce, input, narrow: false },
        &engines,
        "threading-scale reduce",
    );
}

#[test]
fn fuzzed_windows_serve_divergently_bit_equal_to_per_item() {
    // the divergent tier under fuzz: random MIXED windows of generated
    // cases must serve in one pass with results bitwise identical to
    // serving every item alone — on every thread count, every path
    // (including the f32 fast arm: thread/lane placement is never visible)
    for &seed in &SEEDS[..3] {
        let mut rng = Rng::new(seed ^ 0xD1FF);
        let cases: Vec<Case> =
            (0..rng.usize(2, 7)).map(|_| gen_case(&mut rng, None, None)).collect();
        let window: Vec<(&Pipeline, &Tensor)> =
            cases.iter().map(|c| (&c.pipeline, &c.input)).collect();
        for threads in [1usize, 2, 8] {
            let eng = HostFusedEngine::with_threads(threads);
            let out = eng.run_divergent(&window);
            assert_eq!(out.results.len(), window.len());
            assert_eq!(out.launches, 1);
            for (i, ((p, t), res)) in window.iter().zip(&out.results).enumerate() {
                let got = res.as_ref().expect("fuzzed window item serves");
                let alone = eng.run(p, t).unwrap();
                let ctx = format!("seed {seed} t{threads} item {i} sig {}", Signature::of(p));
                assert_bits_eq(got, &alone, &ctx);
            }
            assert_eq!(eng.divergent_runs(), 1);
        }
    }
}

#[test]
fn fault_injected_divergent_windows_fail_alone_and_survivors_stay_bit_equal() {
    // the failure-isolation contract under fuzz: arm the injector against
    // ONE item of a random mixed window (an injected panic once, an
    // injected typed error once); that item must fail alone with the typed
    // error, and every survivor must stay BITWISE identical to a clean
    // per-item engine — fault injection never perturbs its neighbors
    use std::sync::Arc;

    use fkl::exec::LaunchPanic;
    use fkl::faults::{FaultInjector, FaultPlan, InjectedFault};

    for &seed in &SEEDS[..3] {
        let mut rng = Rng::new(seed ^ 0xFA57);
        let n = rng.usize(3, 7);
        let cases: Vec<Case> = (0..n).map(|_| gen_case(&mut rng, None, None)).collect();
        let window: Vec<(&Pipeline, &Tensor)> =
            cases.iter().map(|c| (&c.pipeline, &c.input)).collect();
        let clean = HostFusedEngine::with_threads(2);
        // a single rule's launch counter equals the window index (consulted
        // serially in window order), so `launch=K` targets item K exactly
        for (action, faulted) in [("panic", 0usize), ("err", n - 1)] {
            let spec = format!("tier=divergent,launch={faulted},action={action}");
            let eng = HostFusedEngine::with_threads(2).with_fault_injector(Arc::new(
                FaultInjector::new(FaultPlan::parse(&spec).unwrap()),
            ));
            let out = eng.run_divergent(&window);
            assert_eq!(out.results.len(), n);
            for (i, ((p, t), res)) in window.iter().zip(&out.results).enumerate() {
                let ctx = format!("seed {seed} {action} item {i} sig {}", Signature::of(p));
                if i == faulted {
                    let e = res.as_ref().expect_err("faulted item fails");
                    if action == "panic" {
                        let lp =
                            e.downcast_ref::<LaunchPanic>().expect("panic contained, typed");
                        assert!(lp.msg.contains("injected fault"), "{ctx}: {lp}");
                    } else {
                        let inj =
                            e.downcast_ref::<InjectedFault>().expect("typed injected error");
                        assert_eq!(inj.launch, faulted as u64, "{ctx}");
                    }
                } else {
                    let got =
                        res.as_ref().unwrap_or_else(|e| panic!("{ctx}: survivor failed: {e}"));
                    let alone = clean.run(p, t).expect("clean per-item serve");
                    assert_bits_eq(got, &alone, &ctx);
                }
            }
        }
    }
}
