//! Registry lookups + planner tier selection against the real manifest.
#![cfg(feature = "pjrt")] // drives AOT artifacts through the PJRT runtime

use std::rc::Rc;

use fkl::fusion::{plan_pipeline, FusionPlan, Planner};
use fkl::ops::{Opcode, Pipeline};
use fkl::runtime::Registry;
use fkl::tensor::DType;

fn registry() -> Rc<Registry> {
    Rc::new(Registry::load(fkl::default_artifact_dir()).expect("run `make artifacts`"))
}

#[test]
fn find_chain_exact_lookup() {
    let reg = registry();
    let m = reg
        .find_chain(
            &[Opcode::Nop, Opcode::Mul, Opcode::Sub, Opcode::Div],
            "u8",
            "f32",
            &[60, 120],
            50,
            "pallas",
        )
        .expect("CMSD b50 artifact");
    assert_eq!(m.kind, "chain");
    assert_eq!(m.input_roles, vec!["data", "params"]);
    assert_eq!(m.out_shape, vec![50, 60, 120]);
}

#[test]
fn geometry_block_is_loaded() {
    let reg = registry();
    let hf = reg.geometry["hf_batches"].as_usize_vec().unwrap();
    assert!(hf.contains(&50));
    assert!(reg.geometry["vec_n"].as_usize().unwrap() > 1_000_000);
}

#[test]
fn tier_selection_cascade() {
    let reg = registry();
    // tier 1: exact
    let p = Pipeline::from_opcodes(
        &[(Opcode::Nop, 0.0), (Opcode::Mul, 0.5), (Opcode::Sub, 3.0), (Opcode::Div, 1.7)],
        &[60, 120],
        50,
        DType::U8,
        DType::F32,
    )
    .unwrap();
    assert!(matches!(plan_pipeline(&p, &reg, "pallas").unwrap(), FusionPlan::Exact { .. }));

    // tier 2: staticloop (repeated mul-add with uniform params, u8 60x120 b50)
    let p = Pipeline::from_opcodes(
        &[(Opcode::Mul, 0.9), (Opcode::Add, 0.1), (Opcode::Mul, 0.9), (Opcode::Add, 0.1)],
        &[60, 120],
        50,
        DType::U8,
        DType::U8,
    )
    .unwrap();
    assert!(matches!(plan_pipeline(&p, &reg, "pallas").unwrap(), FusionPlan::StaticLoop { iters: 2, .. }));

    // tier 3: interpreter (arbitrary chain at the interp shape)
    let p = Pipeline::from_opcodes(
        &[(Opcode::Sqrt, 0.0), (Opcode::Exp, 0.0), (Opcode::Min, 1.0)],
        &[256, 256],
        1,
        DType::F32,
        DType::F32,
    )
    .unwrap();
    assert!(matches!(plan_pipeline(&p, &reg, "pallas").unwrap(), FusionPlan::Interp { .. }));

    // tier 4: unfused fallback (chain longer than kmax at a covered shape)
    let chain: Vec<(Opcode, f64)> = (0..20).map(|_| (Opcode::Mul, 1.01)).collect();
    let p = Pipeline::from_opcodes(&chain, &[60, 120], 1, DType::F32, DType::F32).unwrap();
    match plan_pipeline(&p, &reg, "pallas").unwrap() {
        FusionPlan::Unfused { artifacts } => assert_eq!(artifacts.len(), 20),
        other => panic!("expected unfused fallback, got {other:?}"),
    }
}

#[test]
fn planner_stats_accumulate() {
    let reg = registry();
    let mut planner = Planner::default();
    let exact = Pipeline::from_opcodes(
        &[(Opcode::Nop, 0.0), (Opcode::Mul, 0.5), (Opcode::Sub, 3.0), (Opcode::Div, 1.7)],
        &[60, 120],
        50,
        DType::U8,
        DType::F32,
    )
    .unwrap();
    let interp = Pipeline::from_opcodes(
        &[(Opcode::Abs, 0.0)],
        &[256, 256],
        1,
        DType::F32,
        DType::F32,
    )
    .unwrap();
    planner.plan(&exact, &reg).unwrap();
    planner.plan(&exact, &reg).unwrap();
    planner.plan(&interp, &reg).unwrap();
    assert_eq!(planner.stats.exact, 2);
    // abs at 256x256: no exact/staticloop artifact -> interp tier
    assert_eq!(planner.stats.interp, 1);
}

#[test]
fn variant_preference_is_honored() {
    let reg = registry();
    let p = Pipeline::from_opcodes(
        &[(Opcode::Mul, 1.5), (Opcode::Add, 2.0)],
        &[4, 8],
        2,
        DType::F32,
        DType::F32,
    )
    .unwrap();
    let FusionPlan::Exact { artifact } = plan_pipeline(&p, &reg, "xla").unwrap() else {
        panic!("expected exact plan")
    };
    assert!(artifact.ends_with("_xla"), "{artifact}");
    let FusionPlan::Exact { artifact } = plan_pipeline(&p, &reg, "pallas").unwrap() else {
        panic!("expected exact plan")
    };
    assert!(artifact.ends_with("_pallas"), "{artifact}");
}

#[test]
fn compile_cache_counts() {
    let reg = registry();
    assert_eq!(reg.compiled_count(), 0);
    let _ = reg.executable("chain_mul-add_f322f32_4x8_b2_pallas").unwrap();
    let _ = reg.executable("chain_mul-add_f322f32_4x8_b2_pallas").unwrap();
    assert_eq!(reg.compiled_count(), 1, "second fetch must hit the cache");
}
