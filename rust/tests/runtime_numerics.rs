//! Vertical-slice integration: AOT HLO artifacts -> PJRT -> numerics vs hostref.
//!
//! Requires `make artifacts` to have run (skips with a message otherwise —
//! CI always builds artifacts first via the Makefile).
#![cfg(feature = "pjrt")] // drives AOT artifacts through the PJRT runtime

use std::rc::Rc;

use fkl::hostref;
use fkl::ops::{Opcode, Pipeline};
use fkl::runtime::{Executor, Registry};
use fkl::tensor::{DType, Tensor};

fn registry() -> Rc<Registry> {
    Rc::new(Registry::load(fkl::default_artifact_dir()).expect("run `make artifacts` first"))
}

fn assert_close(got: &Tensor, want: &Tensor, tol: f64) {
    assert_eq!(got.shape(), want.shape(), "shape mismatch");
    assert_eq!(got.dtype(), want.dtype(), "dtype mismatch");
    let g = got.to_f64_vec();
    let w = want.to_f64_vec();
    for (i, (a, b)) in g.iter().zip(&w).enumerate() {
        assert!(
            (a - b).abs() <= tol + tol * b.abs(),
            "elem {i}: got {a}, want {b} (tol {tol})"
        );
    }
}

#[test]
fn manifest_loads_and_crosschecks_opcodes() {
    let reg = registry();
    assert!(reg.len() > 50, "expected a full artifact family, got {}", reg.len());
    assert!(reg.get("chain_mul-add_f322f32_4x8_b2_pallas").is_some());
}

#[test]
fn fused_chain_matches_hostref() {
    let reg = registry();
    let exec = Executor::new(reg);
    // artifact: chain mul,add over f32[2,4,8]
    let x: Vec<f32> = (0..64).map(|i| (i as f32) * 0.25 - 4.0).collect();
    let xt = Tensor::from_f32(&x, &[2, 4, 8]);
    let params = Tensor::from_f32(&[1.5, 2.0], &[2]);
    let got = exec.run("chain_mul-add_f322f32_4x8_b2_pallas", &[&xt, &params]).unwrap();

    let p = Pipeline::from_opcodes(
        &[(Opcode::Mul, 1.5), (Opcode::Add, 2.0)],
        &[4, 8],
        2,
        DType::F32,
        DType::F32,
    )
    .unwrap();
    let want = hostref::run_pipeline(&p, &xt);
    assert_close(&got, &want, 1e-5);
}

#[test]
fn pallas_and_xla_variants_agree_exactly() {
    let reg = registry();
    let exec = Executor::new(reg);
    let x: Vec<f32> = (0..64).map(|i| (i as f32) * 0.5).collect();
    let xt = Tensor::from_f32(&x, &[2, 4, 8]);
    let params = Tensor::from_f32(&[0.75, -1.0], &[2]);
    let a = exec.run("chain_mul-add_f322f32_4x8_b2_pallas", &[&xt, &params]).unwrap();
    let b = exec.run("chain_mul-add_f322f32_4x8_b2_xla", &[&xt, &params]).unwrap();
    assert_eq!(a, b, "pallas and xla lowerings of the same chain must agree bitwise");
}

#[test]
fn staticloop_trip_count_is_runtime() {
    let reg = registry();
    let exec = Executor::new(reg);
    let name = "staticloop_mul-add_u82u8_60x120_b50_pallas";
    let n = 50 * 60 * 120;
    let x = Tensor::from_u8(&vec![10u8; n], &[50, 60, 120]);
    let params = Tensor::from_f32(&[1.1, 0.5], &[2]);
    let p = Pipeline::from_opcodes(
        &[(Opcode::Mul, 1.1f32 as f64), (Opcode::Add, 0.5)],
        &[60, 120],
        50,
        DType::U8,
        DType::U8,
    )
    .unwrap();
    for iters in [0usize, 1, 7] {
        let it = Tensor::from_i32(&[iters as i32], &[1]);
        let got = exec.run(name, &[&it, &x, &params]).unwrap();
        let want = hostref::run_staticloop(&p, &x, iters);
        assert_close(&got, &want, 1.0); // u8 rounding tolerance
    }
}

#[test]
fn interp_kernel_runs_arbitrary_chain() {
    let reg = registry();
    let exec = Executor::new(reg);
    let name = "interp_k16_f322f32_256x256_b1_pallas";
    let n = 256 * 256;
    let x: Vec<f32> = (0..n).map(|i| ((i % 97) as f32) * 0.1 - 3.0).collect();
    let xt = Tensor::from_f32(&x, &[1, 256, 256]);
    // chain: mul 2, add 1, abs, min 4  (+ 12 nops)
    let mut opc = vec![0i32; 16];
    let mut par = vec![0f32; 16];
    opc[..4].copy_from_slice(&[
        Opcode::Mul.code(),
        Opcode::Add.code(),
        Opcode::Abs.code(),
        Opcode::Min.code(),
    ]);
    par[..4].copy_from_slice(&[2.0, 1.0, 0.0, 4.0]);
    let opc_t = Tensor::from_i32(&opc, &[16]);
    let par_t = Tensor::from_f32(&par, &[16]);
    let got = exec.run(name, &[&xt, &opc_t, &par_t]).unwrap();

    let p = Pipeline::from_opcodes(
        &[(Opcode::Mul, 2.0), (Opcode::Add, 1.0), (Opcode::Abs, 0.0), (Opcode::Min, 4.0)],
        &[256, 256],
        1,
        DType::F32,
        DType::F32,
    )
    .unwrap();
    let want = hostref::run_pipeline(&p, &xt);
    assert_close(&got, &want, 1e-5);
}

#[test]
fn reduce_stats_one_pass() {
    let reg = registry();
    let exec = Executor::new(reg);
    let n = 512 * 512;
    let x: Vec<f32> = (0..n).map(|i| ((i as f32) * 0.001).sin() * 10.0).collect();
    let xt = Tensor::from_f32(&x, &[512, 512]);
    let got = exec.run("reduce_stats_f32_512x512_pallas", &[&xt]).unwrap();
    let g = got.to_f64_vec();
    let [mx, mn, sum, mean] = hostref::reduce_stats(&xt);
    assert!((g[0] - mx).abs() < 1e-3, "max {} vs {}", g[0], mx);
    assert!((g[1] - mn).abs() < 1e-3, "min {} vs {}", g[1], mn);
    assert!((g[2] - sum).abs() < sum.abs() * 1e-4 + 1.0, "sum {} vs {}", g[2], sum);
    assert!((g[3] - mean).abs() < 1e-3, "mean {} vs {}", g[3], mean);
}

#[test]
fn preproc_pipeline_matches_hostref() {
    use fkl::tensor::{make_frame, Rect};
    let reg = registry();
    let exec = Executor::new(reg);
    let name = "preproc_720x1280x3_to128x64_b2_pallas";
    let frame = make_frame(720, 1280, 42);
    let rects = [Rect::new(100, 50, 120, 60), Rect::new(640, 300, 120, 60)];
    let mulv = [0.9f32, 1.0, 1.1];
    let subv = [0.5f32, 0.4, 0.3];
    let divv = [2.0f32, 2.1, 2.2];
    let rects_t = Rect::batch_tensor(&rects);
    let mul_t = Tensor::from_f32(&mulv, &[3]);
    let sub_t = Tensor::from_f32(&subv, &[3]);
    let div_t = Tensor::from_f32(&divv, &[3]);
    let got = exec.run(name, &[&frame, &rects_t, &mul_t, &sub_t, &div_t]).unwrap();
    let want = hostref::preproc(&frame, &rects, mulv, subv, divv, 128, 64);
    assert_close(&got, &want, 1e-2);
}

#[test]
fn graph_replay_matches_stepwise() {
    use fkl::runtime::ExecGraph;
    let reg = registry();
    let exec = Executor::new(reg.clone());
    // two mul-kernels back to back on the xp04 single-op artifact
    let name = "single_op_mul_u82u8_60x120_b1_pallas";
    let x = Tensor::from_u8(&vec![7u8; 60 * 120], &[1, 60, 120]);
    let params = Tensor::from_f32(&[3.0], &[1]);

    let graph = ExecGraph::record()
        .launch(&exec, &reg, name, &[(1, &params)])
        .unwrap()
        .launch(&exec, &reg, name, &[(1, &params)])
        .unwrap()
        .finish();
    let got = graph.replay(&x).unwrap();

    let step1 = exec.run(name, &[&x, &params]).unwrap();
    let want = exec.run(name, &[&step1, &params]).unwrap();
    assert_eq!(got, want);
}
