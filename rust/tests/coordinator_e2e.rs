//! Coordinator end-to-end: submit -> dynamic HF batch -> fused launch ->
//! reply, with correctness, ordering, metrics and backpressure checks.
//!
//! These tests run WITHOUT artifacts: `EngineSelect::Auto` degrades to the
//! host fused engine when the registry is unavailable, so the coordinator's
//! behavior (batching, backpressure, draining, numerics vs hostref) is
//! verified on every machine; with artifacts present the same tests exercise
//! the XLA path.

use std::time::Duration;

use fkl::chain::{Add, Chain, ConvertTo, Div, Mul, Sub, F32, U8};
use fkl::coordinator::{BatchPolicy, EngineSelect, Service, ServiceConfig};
use fkl::ops::Pipeline;
use fkl::proplite::Rng;
use fkl::tensor::Tensor;

fn pipeline() -> Pipeline {
    // every coordinator stream is built through the typed chain front door
    Chain::read::<U8>(&[60, 120])
        .map(ConvertTo)
        .map(Mul(0.5))
        .map(Sub(3.0))
        .map(Div(1.7))
        .cast::<F32>()
        .write()
        .into_pipeline()
}

#[test]
fn requests_are_batched_and_correct() {
    let svc = Service::start(ServiceConfig {
        artifact_dir: None,
        queue_cap: 512,
        policy: BatchPolicy { max_batch: 25, window: Duration::from_micros(300), ..Default::default() },
        ..ServiceConfig::default()
    });
    let p = pipeline();
    let mut rng = Rng::new(1);
    let n = 100;
    let mut inputs = Vec::new();
    let mut rxs = Vec::new();
    for _ in 0..n {
        let item = Tensor::from_u8(&rng.vec_u8(7200), &[1, 60, 120]);
        inputs.push(item.clone());
        rxs.push(svc.submit(p.clone(), item).unwrap());
    }
    for (i, rx) in rxs.into_iter().enumerate() {
        let out = rx.recv().expect("service alive").expect("request ok");
        let want = fkl::hostref::run_pipeline(&p, &inputs[i]);
        let (g, w) = (out.to_f64_vec(), want.to_f64_vec());
        for (a, b) in g.iter().zip(&w) {
            assert!((a - b).abs() < 1e-3, "request {i}");
        }
    }
    let m = svc.metrics().unwrap();
    assert_eq!(m.completed, n as u64);
    assert!(m.mean_batch() > 1.5, "batching should engage: mean {}", m.mean_batch());
    assert_eq!(m.failed, 0);
    svc.shutdown();
}

#[test]
fn single_item_latency_path_works() {
    let svc = Service::start(ServiceConfig {
        artifact_dir: None,
        queue_cap: 16,
        policy: BatchPolicy { max_batch: 50, window: Duration::from_micros(100), ..Default::default() },
        ..ServiceConfig::default()
    });
    let p = pipeline();
    let item = Tensor::from_u8(&vec![100u8; 7200], &[1, 60, 120]);
    let rx = svc.submit(p.clone(), item.clone()).unwrap();
    let out = rx.recv().unwrap().unwrap();
    assert_eq!(out.shape(), &[1, 60, 120]);
    let want = fkl::hostref::run_pipeline(&p, &item);
    assert_eq!(out.shape(), want.shape());
    svc.shutdown();
}

#[test]
fn param_divergent_requests_in_one_window_stay_correct() {
    // the batcher groups by the param-agnostic stream key; a stacked launch
    // binds ONE param set — divergent-param company must be served with ITS
    // OWN params (per item), never silently with the head request's
    let svc = Service::start(ServiceConfig {
        artifact_dir: None,
        queue_cap: 64,
        policy: BatchPolicy { max_batch: 16, window: Duration::from_millis(20), ..Default::default() },
        engine: EngineSelect::HostFused,
        ..ServiceConfig::default()
    });
    let mk = |mul: f64| {
        Chain::read::<U8>(&[10, 10]).map(Mul(mul)).cast::<F32>().write().into_pipeline()
    };
    let item = Tensor::from_u8(&vec![10u8; 100], &[1, 10, 10]);
    // same signature (param-agnostic), different params, one batch window
    let rx_a = svc.submit(mk(2.0), item.clone()).unwrap();
    let rx_b = svc.submit(mk(5.0), item.clone()).unwrap();
    let a = rx_a.recv().unwrap().unwrap();
    let b = rx_b.recv().unwrap().unwrap();
    assert_eq!(a.as_f32().unwrap()[0], 20.0, "head request served with its params");
    assert_eq!(b.as_f32().unwrap()[0], 50.0, "divergent request served with ITS params");
    let m = svc.metrics().unwrap();
    assert_eq!(m.completed, 2);
    assert_eq!(m.failed, 0);
    svc.shutdown();
}

#[test]
fn reduce_chains_are_servable_traffic() {
    use fkl::ops::ReduceKind;
    // reduce-terminated chains serve through the coordinator like any other
    // stream (per item — statistics summarize one request), and the serve
    // lands in the new reduce tier of the planner metrics
    let svc = Service::start(ServiceConfig {
        artifact_dir: None,
        queue_cap: 64,
        policy: BatchPolicy { max_batch: 8, window: Duration::from_micros(200), ..Default::default() },
        engine: EngineSelect::HostFused,
        ..ServiceConfig::default()
    });
    let p = Chain::read::<U8>(&[40, 30])
        .map(Mul(0.5))
        .reduce(ReduceKind::Mean)
        .into_pipeline();
    let mut rng = Rng::new(5);
    let mut inputs = Vec::new();
    let mut rxs = Vec::new();
    for _ in 0..6 {
        let item = Tensor::from_u8(&rng.vec_u8(1200), &[1, 40, 30]);
        inputs.push(item.clone());
        rxs.push(svc.submit(p.clone(), item).unwrap());
    }
    for (i, rx) in rxs.into_iter().enumerate() {
        let out = rx.recv().expect("service alive").expect("request ok");
        assert_eq!(out.shape(), &[1], "request {i}");
        let want = fkl::hostref::run_pipeline(&p, &inputs[i]);
        assert_eq!(out, want, "request {i}: bit-equal statistics");
    }
    let m = svc.metrics().unwrap();
    assert_eq!(m.completed, 6);
    assert!(m.planner.reduction >= 6, "reduce serves visible in metrics");
    assert_eq!(m.failed, 0);
    svc.shutdown();
}

#[test]
fn signature_divergent_window_is_served_by_the_divergent_tier_in_one_pass() {
    // the acceptance shape: one coordinator window mixing FOUR distinct
    // pipeline signatures — a param-divergent dense pair, a lane-structured
    // dense body, a structured resize->split read and a reduce terminator —
    // served by the divergent-HF tier, bit-equal to per-item serving
    use fkl::chain::{CvtColor, MulC3};
    use fkl::ops::ReduceKind;
    use fkl::tensor::{make_frame, Rect};
    let svc = Service::start(ServiceConfig {
        artifact_dir: None,
        queue_cap: 64,
        policy: BatchPolicy { max_batch: 32, window: Duration::from_millis(25), ..Default::default() },
        engine: EngineSelect::HostFused,
        ..ServiceConfig::default()
    });
    let mk_dense = |mul: f64| {
        Chain::read::<U8>(&[8, 9]).map(Mul(mul)).cast::<F32>().write().into_pipeline()
    };
    let lanes = Chain::read::<U8>(&[4, 3, 3])
        .map(CvtColor)
        .map(MulC3([0.5, 1.0, 1.5]))
        .cast::<F32>()
        .write()
        .into_pipeline();
    let structured = Chain::read_resize::<U8>(Rect::new(3, 2, 20, 14), 10, 6)
        .map(CvtColor)
        .cast::<F32>()
        .write_split()
        .into_pipeline();
    let reduce = Chain::read::<U8>(&[8, 9])
        .map(Mul(0.5))
        .reduce_per_channel(ReduceKind::Mean)
        .into_pipeline();

    let mut rng = Rng::new(31);
    let item = Tensor::from_u8(&rng.vec_u8(72), &[1, 8, 9]);
    let lane_item = Tensor::from_u8(&rng.vec_u8(36), &[1, 4, 3, 3]);
    let frame = make_frame(40, 50, 12);
    let requests: Vec<(Pipeline, Tensor)> = vec![
        (mk_dense(2.0), item.clone()),
        (lanes, lane_item),
        (structured, frame),
        (mk_dense(5.0), item.clone()),
        (reduce, item),
    ];
    // submit the whole window in one tight burst so it ages out together
    let rxs: Vec<_> = requests
        .iter()
        .map(|(p, t)| svc.submit(p.clone(), t.clone()).unwrap())
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let out = rx.recv().expect("service alive").expect("request ok");
        let (p, t) = &requests[i];
        assert_eq!(out, fkl::hostref::run_pipeline(p, t), "request {i}: bit-equal");
    }
    let m = svc.metrics().unwrap();
    assert_eq!(m.completed, 5);
    assert_eq!(m.failed, 0);
    assert!(m.planner.divergent >= 1, "the divergent tier served: {:?}", m.planner);
    assert!(m.divergent_windows >= 1, "window metrics surface");
    assert!(m.divergent_items >= 4, "the mixed requests shared a pass");
    assert!(m.divergent_occupancy() > 0.0 && m.divergent_occupancy() <= 1.0);
    assert!(m.planner.structured >= 1, "the structured item stays observable");
    assert!(m.planner.reduction >= 1, "the reduce item stays observable");
    svc.shutdown();
}

#[test]
fn backpressure_rejects_when_full() {
    // a tiny queue with a long window: most submissions must fail fast
    // rather than block (the paper's production pipelines drop frames)
    let svc = Service::start(ServiceConfig {
        artifact_dir: None,
        queue_cap: 2,
        policy: BatchPolicy { max_batch: 64, window: Duration::from_secs(5), ..Default::default() },
        ..ServiceConfig::default()
    });
    let p = pipeline();
    let mut results = Vec::new();
    for _ in 0..50 {
        let item = Tensor::from_u8(&vec![1u8; 7200], &[1, 60, 120]);
        results.push(svc.submit(p.clone(), item).is_ok());
    }
    let rejected = results.iter().filter(|ok| !**ok).count();
    assert!(rejected > 0, "tiny queue + slow window must shed load");
    svc.shutdown();
}

#[test]
fn mixed_streams_are_not_cross_batched() {
    let svc = Service::start(ServiceConfig {
        artifact_dir: None,
        queue_cap: 512,
        policy: BatchPolicy { max_batch: 16, window: Duration::from_micros(300), ..Default::default() },
        ..ServiceConfig::default()
    });
    // stream A: CMSD u8->f32; stream B: plain mul f32->f32 (interp tier)
    let pa = pipeline();
    let pb = Chain::read::<F32>(&[256, 256]).map(Mul(2.0)).write().into_pipeline();
    let mut rng = Rng::new(2);
    let mut rx_all = Vec::new();
    for i in 0..20 {
        if i % 2 == 0 {
            let item = Tensor::from_u8(&rng.vec_u8(7200), &[1, 60, 120]);
            rx_all.push(("a", svc.submit(pa.clone(), item).unwrap()));
        } else {
            let item = Tensor::from_f32(&rng.vec_f32(256 * 256, 0.0, 1.0), &[1, 256, 256]);
            rx_all.push(("b", svc.submit(pb.clone(), item).unwrap()));
        }
    }
    for (stream, rx) in rx_all {
        let out = rx.recv().unwrap().unwrap_or_else(|e| panic!("stream {stream}: {e}"));
        match stream {
            "a" => assert_eq!(out.shape(), &[1, 60, 120]),
            _ => assert_eq!(out.shape(), &[1, 256, 256]),
        }
    }
    svc.shutdown();
}

#[test]
fn shutdown_drains_pending_work() {
    let svc = Service::start(ServiceConfig {
        artifact_dir: None,
        queue_cap: 512,
        // huge window: requests would sit forever without the drain
        policy: BatchPolicy { max_batch: 64, window: Duration::from_secs(60), ..Default::default() },
        ..ServiceConfig::default()
    });
    let p = pipeline();
    let mut rxs = Vec::new();
    for _ in 0..10 {
        let item = Tensor::from_u8(&vec![5u8; 7200], &[1, 60, 120]);
        rxs.push(svc.submit(p.clone(), item).unwrap());
    }
    svc.shutdown(); // must flush, not drop
    let mut ok = 0;
    for rx in rxs {
        if let Ok(Ok(_)) = rx.recv() {
            ok += 1;
        }
    }
    assert_eq!(ok, 10, "shutdown must drain pending requests");
}

#[test]
fn shutdown_under_load_resolves_every_reply() {
    // the hostile variant: a tiny ingress queue kept FULL while shutdown()
    // runs. Shutdown must never block on the full queue (it try_sends and
    // drops the sender), and every accepted request must still resolve —
    // served or typed-failed, never a hung receiver.
    let svc = Service::start(ServiceConfig {
        artifact_dir: None,
        queue_cap: 4,
        // huge window: nothing launches until the drain
        policy: BatchPolicy { max_batch: 64, window: Duration::from_secs(60), ..Default::default() },
        engine: EngineSelect::HostFused,
        ..ServiceConfig::default()
    });
    let p = pipeline();
    let mut rxs = Vec::new();
    for _ in 0..64 {
        let item = Tensor::from_u8(&vec![5u8; 7200], &[1, 60, 120]);
        if let Ok(rx) = svc.submit(p.clone(), item) {
            rxs.push(rx);
        }
    }
    let accepted = rxs.len();
    assert!(accepted > 0, "some submissions must get through");
    svc.shutdown();
    let mut resolved = 0;
    for rx in rxs {
        // recv() returns once the service replied or dropped the slot; a
        // drop without reply would still return (Err), but a HUNG channel
        // would deadlock this loop — the assertion is that we get here
        if rx.recv().is_ok() {
            resolved += 1;
        }
    }
    assert_eq!(resolved, accepted, "every accepted request resolves through shutdown");
}

#[test]
fn structured_chains_are_servable_traffic() {
    // the flagship preproc shape submitted as coordinator traffic: items are
    // shared FRAMES (not [1, *shape] planes), served per request on the host
    // tier, counted as structured in PlannerStats
    use fkl::chain::{CvtColor, MulC3};
    use fkl::tensor::{make_frame, Rect};
    let svc = Service::start(ServiceConfig {
        artifact_dir: None,
        queue_cap: 64,
        policy: BatchPolicy { max_batch: 8, window: Duration::from_micros(200), ..Default::default() },
        engine: EngineSelect::HostFused,
        ..ServiceConfig::default()
    });
    let typed = Chain::read_resize::<U8>(Rect::new(4, 6, 30, 18), 24, 12)
        .map(CvtColor)
        .map(MulC3([0.9, 1.0, 1.1]))
        .cast::<F32>()
        .write_split();
    let p: Pipeline = typed.pipeline().clone();
    let mut rxs = Vec::new();
    let mut frames = Vec::new();
    for i in 0..6u64 {
        let frame = make_frame(60, 80, 100 + i);
        frames.push(frame.clone());
        rxs.push(svc.submit(typed.clone(), frame).unwrap());
    }
    for (i, rx) in rxs.into_iter().enumerate() {
        let out = rx.recv().expect("service alive").expect("structured request ok");
        assert_eq!(out.shape(), &[1, 3, 24, 12]);
        let want = fkl::hostref::run_pipeline(&p, &frames[i]);
        assert_eq!(out, want, "request {i}: f64-accumulated path is bit-equal");
    }
    // a wrong-dtype frame fails loudly without poisoning the stream
    let bad = svc.submit(p.clone(), Tensor::from_f32(&vec![0.0; 60 * 80 * 3], &[60, 80, 3]));
    assert!(bad.unwrap().recv().unwrap().is_err());
    let m = svc.metrics().unwrap();
    assert_eq!(m.completed, 6);
    assert_eq!(m.failed, 1);
    assert!(m.planner.structured >= 6, "structured serves visible in metrics");
    assert!(m.planner.host >= 6);
    svc.shutdown();
}

#[test]
fn canonicalizing_ingress_serves_equivalent_chains_from_one_cached_plan() {
    use fkl::ops::Opcode;
    use fkl::tensor::DType;
    // four syntactically DISTINCT but bit-equivalent u8->f64 chains (dead
    // identity stages, a Neg;Neg pair, a trailing Sub(+0.0)): with
    // `ServiceConfig::canonicalize` on, ingress rewrites every admission to
    // the shared canonical form, so one scheduling window stacks ALL of
    // them into the same HF launches and the engine compiles ONE plan
    let variants: Vec<Pipeline> = [
        vec![(Opcode::Mul, 0.5), (Opcode::Add, 1.0)],
        vec![(Opcode::Mul, 0.5), (Opcode::Mul, 1.0), (Opcode::Add, 1.0)],
        vec![(Opcode::Mul, 0.5), (Opcode::Neg, 0.0), (Opcode::Neg, 0.0), (Opcode::Add, 1.0)],
        vec![(Opcode::Nop, 0.0), (Opcode::Mul, 0.5), (Opcode::Add, 1.0), (Opcode::Sub, 0.0)],
    ]
    .iter()
    .map(|ops| Pipeline::from_opcodes(ops, &[6, 8], 1, DType::U8, DType::F64).unwrap())
    .collect();
    assert_eq!(
        variants.iter().map(|p| p.body().len()).collect::<Vec<_>>(),
        vec![2, 3, 4, 4],
        "the variants really are syntactically distinct"
    );

    let svc = Service::start(ServiceConfig {
        artifact_dir: None,
        queue_cap: 128,
        // one generous window so the whole burst schedules together
        policy: BatchPolicy { max_batch: 32, window: Duration::from_millis(250), ..Default::default() },
        engine: EngineSelect::HostFused,
        canonicalize: true,
        ..ServiceConfig::default()
    });
    let mut rng = Rng::new(9);
    let mut submitted = Vec::new();
    for _ in 0..3 {
        for p in &variants {
            let item = Tensor::from_u8(&rng.vec_u8(48), &[1, 6, 8]);
            let rx = svc.submit(p.clone(), item.clone()).unwrap();
            submitted.push((p.clone(), item, rx));
        }
    }
    for (i, (p, item, rx)) in submitted.into_iter().enumerate() {
        let out = rx.recv().expect("service alive").expect("request ok");
        let want = fkl::hostref::run_pipeline(&p, &item);
        // u8 -> f64 is an f64-accumulated path: canonical serving must be
        // BIT-equal to the raw chain's oracle, not merely close
        assert_eq!(out, want, "request {i}: canonical serving is bit-equal to the raw chain");
    }
    let m = svc.metrics().unwrap();
    assert_eq!(m.completed, 12);
    assert_eq!(m.failed, 0);
    assert!(m.rewrites_applied > 0, "ingress applied real rewrites: {m:?}");
    assert!(m.lints_emitted >= 12, "every admission was linted: {}", m.lints_emitted);
    assert_eq!(m.canonical_cache_hits, 11, "first admission seeds the canonical stream");
    assert_eq!(m.planner.plan_cache, 1, "ONE cached plan served every variant: {:?}", m.planner);
    assert!(m.mean_batch() > 1.5, "equivalent chains stacked: mean {}", m.mean_batch());
    svc.shutdown();

    // control: same burst with canonicalization off — every raw signature
    // compiles its own plan and the canon counters stay untouched
    let svc = Service::start(ServiceConfig {
        artifact_dir: None,
        queue_cap: 128,
        policy: BatchPolicy { max_batch: 32, window: Duration::from_millis(250), ..Default::default() },
        engine: EngineSelect::HostFused,
        ..ServiceConfig::default()
    });
    let mut rxs = Vec::new();
    for _ in 0..3 {
        for p in &variants {
            let item = Tensor::from_u8(&rng.vec_u8(48), &[1, 6, 8]);
            rxs.push(svc.submit(p.clone(), item).unwrap());
        }
    }
    for rx in rxs {
        rx.recv().expect("service alive").expect("request ok");
    }
    let m = svc.metrics().unwrap();
    assert_eq!(m.rewrites_applied, 0);
    assert_eq!(m.lints_emitted, 0);
    assert_eq!(m.canonical_cache_hits, 0);
    assert!(
        m.planner.plan_cache >= 4,
        "without canonicalization each raw signature compiled its own plan: {:?}",
        m.planner
    );
    svc.shutdown();
}

#[test]
fn sub_window_deadline_is_served_not_expired() {
    // THE deadline-blind-batcher regression: a deadline (100us) shorter
    // than the batch window (500us) on an otherwise idle service. The old
    // batcher woke only at window fires, so every such request expired
    // unserved no matter how idle the machine was. The deadline-aware
    // batcher wakes at min(window fire, deadline - slack) and pops the
    // request while it is still live.
    let svc = Service::start(ServiceConfig {
        artifact_dir: None,
        queue_cap: 64,
        policy: BatchPolicy { max_batch: 50, window: Duration::from_micros(500), ..Default::default() },
        engine: EngineSelect::HostFused,
        ..ServiceConfig::default()
    });
    let p = Chain::read::<U8>(&[8, 8]).map(Mul(2.0)).cast::<F32>().write().into_pipeline();
    let item = Tensor::from_u8(&vec![3u8; 64], &[1, 8, 8]);
    // warm up: backend construction + plan compile happen before any
    // deadline is on the clock
    let w = svc.submit(p.clone(), item.clone()).unwrap();
    let _ = w.recv();

    // wall-clock tightness (100us end to end) can lose to scheduling noise
    // on a loaded runner, so a few attempts are allowed — the broken
    // batcher failed ALL of them, deterministically
    let mut served = 0;
    for i in 0..20 {
        let rx = svc
            .submit_with_deadline(p.clone(), item.clone(), Duration::from_micros(100))
            .unwrap();
        match rx.recv().expect("service alive") {
            Ok(out) => {
                assert_eq!(out, fkl::hostref::run_pipeline(&p, &item), "attempt {i}");
                served += 1;
            }
            Err(e) => assert!(
                matches!(
                    e,
                    fkl::coordinator::ServeError::Shed | fkl::coordinator::ServeError::Expired
                ),
                "attempt {i}: unexpected error {e}"
            ),
        }
    }
    assert!(
        served >= 1,
        "an idle service must serve sub-window deadlines (0/20 made it — the \
         batcher is deadline-blind again)"
    );
    svc.shutdown();
}

#[test]
fn requests_aged_past_deadline_in_the_ingress_channel_are_shed_not_expired() {
    // The DOA boundary regression: admission control once compared the
    // deadline against `req.enqueued` instead of `Instant::now()`, so a
    // request whose deadline lapsed while it waited in the ingress channel
    // slipped past the shed check, wasted a batcher wake, and came back
    // `Expired`. The fix sheds it at ingest. Construction: a huge request
    // occupies the single service thread, the deadlined victim ages in the
    // channel behind it.
    let svc = Service::start(ServiceConfig {
        artifact_dir: None,
        queue_cap: 64,
        policy: BatchPolicy { max_batch: 1, window: Duration::from_micros(100), ..Default::default() },
        engine: EngineSelect::HostFused,
        ..ServiceConfig::default()
    });
    let slow = Chain::read::<F32>(&[4096, 2048])
        .map(Mul(1.01))
        .map(Add(0.5))
        .map(Sub(0.25))
        .map(Div(1.7))
        .map(Mul(0.99))
        .write()
        .into_pipeline();
    let slow_item = Tensor::from_f32(&vec![1.0f32; 4096 * 2048], &[1, 4096, 2048]);
    let quick = Chain::read::<U8>(&[8, 8]).map(Mul(2.0)).cast::<F32>().write().into_pipeline();
    let quick_item = Tensor::from_u8(&vec![3u8; 64], &[1, 8, 8]);

    let mut shed_seen = false;
    for attempt in 0..5 {
        let slow_rx = svc.submit(slow.clone(), slow_item.clone()).unwrap();
        // let the service thread pick the slow launch up, then park the
        // victim in the channel where its 500us deadline lapses
        std::thread::sleep(Duration::from_millis(1));
        let rx = svc
            .submit_with_deadline(quick.clone(), quick_item.clone(), Duration::from_micros(500))
            .unwrap();
        match rx.recv().expect("service alive") {
            // the boundary under test: aged-in-channel means SHED (typed,
            // at ingest) — never Expired (which would mean it got queued)
            Err(fkl::coordinator::ServeError::Shed) => shed_seen = true,
            Err(fkl::coordinator::ServeError::Expired) => {
                panic!("attempt {attempt}: aged-in-channel request was queued then expired")
            }
            Ok(_) => {} // lost the race to a fast machine; try again
            Err(e) => panic!("attempt {attempt}: unexpected error {e}"),
        }
        let _ = slow_rx.recv();
        if shed_seen {
            break;
        }
    }
    assert!(shed_seen, "the slow launch never aged the victim — shed path untested");

    // the shed satellite: shed requests record latency like every other
    // resolution, so admission churn stays visible in the distribution
    let m = svc.metrics().unwrap();
    assert!(m.shed >= 1, "shed counter advanced");
    assert_eq!(m.expired, 0, "nothing took the expired path");
    assert!(
        m.latency_hist.count() >= m.completed + m.shed,
        "shed requests record latency: {} observations < {} + {}",
        m.latency_hist.count(),
        m.completed,
        m.shed
    );
    svc.shutdown();
}

#[test]
fn host_backend_batches_any_stream_with_exact_numerics() {
    // pinned host engine: a stream no artifact family covers (exotic shape,
    // u8 out) is still HF-batched and must be BIT-equal to the oracle
    let svc = Service::start(ServiceConfig {
        artifact_dir: None,
        queue_cap: 512,
        policy: BatchPolicy { max_batch: 16, window: Duration::from_micros(300), ..Default::default() },
        engine: EngineSelect::HostFused,
        ..ServiceConfig::default()
    });
    // submit() accepts the typed chain directly: the coordinator is a chain
    // front door, lowering happens at the call boundary
    let typed = Chain::read::<U8>(&[17, 23]).map(Mul(1.9)).map(Add(7.0)).map(Sub(20.0)).write();
    let p: Pipeline = typed.pipeline().clone();
    let mut rng = Rng::new(12);
    let n = 40;
    let mut inputs = Vec::new();
    let mut rxs = Vec::new();
    for _ in 0..n {
        let item = Tensor::from_u8(&rng.vec_u8(17 * 23), &[1, 17, 23]);
        inputs.push(item.clone());
        rxs.push(svc.submit(typed.clone(), item).unwrap());
    }
    for (i, rx) in rxs.into_iter().enumerate() {
        let out = rx.recv().expect("service alive").expect("request ok");
        let want = fkl::hostref::run_pipeline(&p, &inputs[i]);
        assert_eq!(out, want, "request {i}: integer dtypes must be bit-equal");
    }
    let m = svc.metrics().unwrap();
    assert_eq!(m.completed, n as u64);
    assert_eq!(m.failed, 0);
    assert!(m.mean_batch() > 1.5, "HF batching must engage: {}", m.mean_batch());
    assert_eq!(m.unfused_fallbacks, 0);
    assert_eq!(m.planner.unfused, 0);
    assert!(m.planner.host > 0, "host tier must be visible in metrics");
    assert_eq!(m.fused_coverage(), 1.0);
    svc.shutdown();
}
