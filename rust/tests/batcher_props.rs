//! Property tests (proplite) for the deadline-aware dynamic batcher: a
//! synthetic-clock simulation that drives `pop_ready` at exactly the wake
//! instants `next_deadline` reports — the same contract the service loop
//! relies on — over random streams, windows, and deadlines.

use std::time::{Duration, Instant};

use fkl::coordinator::{BatchPolicy, Batcher, PendingRequest};
use fkl::ops::{Opcode, Pipeline};
use fkl::proplite::{forall, Rng};
use fkl::tensor::{DType, Tensor};

/// A request on stream `stream` with per-stream sequence number `seq` (the
/// reply slot carries both so the properties can check FIFO without a
/// channel). The stream is encoded in the pipeline SHAPE: distinct shapes
/// are distinct stream keys.
fn req(
    stream: usize,
    seq: u32,
    enqueued: Instant,
    deadline: Option<Instant>,
) -> PendingRequest<(usize, u32)> {
    let w = 2 + stream;
    let pipeline =
        Pipeline::from_opcodes(&[(Opcode::Mul, 1.0)], &[2, w], 1, DType::F32, DType::F32)
            .unwrap();
    PendingRequest {
        pipeline,
        item: Tensor::from_f32(&vec![0.0; 2 * w], &[1, 2, w]),
        enqueued,
        deadline,
        reply: (stream, seq),
        trace_id: 0,
        trace_verdict: 0,
        admitted: enqueued,
    }
}

#[test]
fn prop_batcher_conserves_fifo_and_never_serves_expired() {
    forall(150, |rng| {
        let policy = BatchPolicy {
            max_batch: rng.usize(1, 9),
            window: Duration::from_micros(rng.range_u64(0, 5_000)),
            deadline_slack: Duration::from_micros(rng.range_u64(0, 500)),
        };
        let mut b = Batcher::new(policy);
        // synthetic clock: all instants are offsets from one base, so the
        // simulation is deterministic regardless of how slowly the test runs
        let base = Instant::now();
        let n_streams = rng.usize(1, 5);
        let n = rng.usize(1, 41);
        let mut seqs = vec![0u32; n_streams];
        let mut t = base;
        for _ in 0..n {
            let stream = rng.usize(0, n_streams);
            // arrivals are nondecreasing in time (pushes happen in arrival
            // order, like the service loop's ingest)
            t += Duration::from_micros(rng.range_u64(0, 300));
            let deadline = if rng.bool() {
                Some(t + Duration::from_micros(rng.range_u64(1, 8_000)))
            } else {
                None
            };
            b.push(req(stream, seqs[stream], t, deadline));
            seqs[stream] += 1;
        }

        // drive the batcher the way the service loop does: pop everything
        // ready at `now`, then sleep to the reported next wake instant
        let mut now = t;
        let mut popped_total = 0usize;
        let mut next_expected = vec![0u32; n_streams];
        let mut rounds = 0;
        while b.pending() > 0 {
            rounds += 1;
            assert!(rounds < 10_000, "simulation must terminate");
            while let Some(g) = b.pop_ready(now) {
                let total = g.live.len() + g.expired.len();
                assert!((1..=policy.max_batch).contains(&total), "group size bounded");
                popped_total += total;
                // NOTHING in the live half is past its deadline at the pop
                // instant — expired work is never handed out as servable
                for r in &g.live {
                    assert!(!r.expired(now), "live half contains an expired request");
                }
                for r in &g.expired {
                    assert!(r.expired(now), "expired half must be genuinely past deadline");
                }
                // one group = one stream, drained as a contiguous FIFO
                // prefix; both halves individually preserve arrival order
                let stream = g.live.first().or(g.expired.first()).unwrap().reply.0;
                let mut all: Vec<u32> = g
                    .live
                    .iter()
                    .chain(g.expired.iter())
                    .map(|r| {
                        assert_eq!(r.reply.0, stream, "a group never mixes streams");
                        r.reply.1
                    })
                    .collect();
                for half in [&g.live, &g.expired] {
                    let s: Vec<u32> = half.iter().map(|r| r.reply.1).collect();
                    assert!(s.windows(2).all(|w| w[0] < w[1]), "FIFO-stable split: {s:?}");
                }
                all.sort_unstable();
                let want: Vec<u32> =
                    (next_expected[stream]..next_expected[stream] + all.len() as u32).collect();
                assert_eq!(all, want, "stream {stream}: contiguous FIFO prefix");
                next_expected[stream] += all.len() as u32;
            }
            if b.pending() == 0 {
                break;
            }
            let wake = b.next_deadline().expect("pending work always has a wake instant");
            // the wake hint must make progress: at the wake instant some
            // group is ready (otherwise the service loop would spin)
            now = now.max(wake);
        }
        assert_eq!(popped_total, n, "every request popped exactly once");
    });
}

#[test]
fn prop_no_group_fires_before_window_and_deadline_allow() {
    // below max_batch, with every deadline lax, the ONLY legal fire instant
    // is the window fire — popping earlier would trade batch width for
    // nothing, popping later starves the group
    forall(150, |rng| {
        let window = Duration::from_micros(rng.range_u64(1_000, 20_000));
        let slack = Duration::from_micros(rng.range_u64(0, 500));
        let policy = BatchPolicy { max_batch: rng.usize(2, 10), window, deadline_slack: slack };
        let mut b = Batcher::new(policy);
        let base = Instant::now();
        let k = rng.usize(1, policy.max_batch); // strictly under max_batch
        for i in 0..k {
            // lax deadline: far beyond the window even after slack
            let deadline = if rng.bool() {
                Some(base + window + window + slack + Duration::from_millis(50))
            } else {
                None
            };
            b.push(req(0, i as u32, base, deadline));
        }
        assert!(
            b.pop_ready(base + window - Duration::from_micros(1)).is_none(),
            "not ready one tick before the window fires"
        );
        assert_eq!(
            b.next_deadline(),
            Some(base + window),
            "with lax deadlines the wake instant IS the window fire"
        );
        let g = b.pop_ready(base + window).expect("ready once the window fires");
        assert_eq!(g.live.len(), k, "whole group pops live");
        assert!(g.expired.is_empty());
    });
}

#[test]
fn prop_urgent_deadline_always_beats_the_window() {
    // a member whose deadline (minus slack) precedes the window fire must
    // pull the wake instant forward AND make the group ready at that wake —
    // the regression class behind the deadline-blind batcher bug
    forall(150, |rng| {
        let window = Duration::from_micros(rng.range_u64(5_000, 50_000));
        let slack = Duration::from_micros(rng.range_u64(0, 1_000));
        let policy = BatchPolicy { max_batch: 64, window, deadline_slack: slack };
        let mut b = Batcher::new(policy);
        let base = Instant::now();
        // company first, then the urgent member (deadline well inside the window)
        for i in 0..rng.usize(0, 4) {
            b.push(req(0, i as u32, base, None));
        }
        let deadline = base + Duration::from_micros(rng.range_u64(1_000, 4_000));
        b.push(req(0, 99, base, Some(deadline)));
        let wake = b.next_deadline().expect("wake instant exists");
        assert!(wake < base + window, "deadline pulls the wake before the window fire");
        assert!(wake <= deadline, "the wake instant never lands past the deadline");
        let g = b.pop_ready(wake).expect("group is ready at the reported wake");
        assert!(
            g.live.iter().any(|r| r.reply.1 == 99),
            "the urgent member comes out live at its wake instant"
        );
        assert!(g.expired.is_empty(), "nothing expired: we woke in time");
    });
}
