//! The typed chain front door: engine <-> oracle equivalence for pipelines
//! built through `fkl::chain`, plus the runtime pins for the compile-fail
//! doctests.
//!
//! The compile-time half of the contract lives in `src/chain/mod.rs` as
//! `compile_fail` doctests (missing write, missing read, interior mem-op,
//! dtype-boundary mismatch). Each of those is pinned ONE-TO-ONE here against
//! the `PipelineError` variant the lowered runtime IR still enforces, so the
//! typed layer can never drift ahead of the IR it lowers to.

use fkl::chain::{
    build_erased, Add, Chain, ComputeOp, ConvertTo, Div, Mul, Sub, F32 as CF32, F64 as CF64,
    U8 as CU8,
};
use fkl::exec::{Engine, HostFusedEngine};
use fkl::hostref;
use fkl::ops::{IOp, MemOp, Opcode, Pipeline, PipelineError};
use fkl::proplite::{forall, Rng};
use fkl::tensor::{DType, Tensor};

const DTYPES: [DType; 5] = [DType::U8, DType::U16, DType::I32, DType::F32, DType::F64];

fn rand_tensor(rng: &mut Rng, shape: &[usize], dt: DType) -> Tensor {
    let n: usize = shape.iter().product();
    let vals: Vec<f64> = (0..n).map(|_| rng.f64(0.0, 200.0)).collect();
    Tensor::from_f64_cast(&vals, shape, dt)
}

// --- runtime pins for the compile_fail doctests ----------------------------

#[test]
fn pin_missing_write_is_still_enforced_by_the_ir() {
    // compile_fail twin: an unsealed chain is not a TypedPipeline
    let e = Pipeline::new(
        vec![IOp::Mem(MemOp::Read { dtype: DType::F32 }), IOp::compute(Opcode::Mul, 2.0)],
        vec![4],
        1,
        DType::F32,
        DType::F32,
    )
    .unwrap_err();
    assert_eq!(e, PipelineError::MissingWrite);
}

#[test]
fn pin_missing_read_is_still_enforced_by_the_ir() {
    // compile_fail twin: ChainLink cannot be assembled without a read
    let e = Pipeline::new(
        vec![IOp::compute(Opcode::Mul, 2.0), IOp::Mem(MemOp::Write { dtype: DType::F32 })],
        vec![4],
        1,
        DType::F32,
        DType::F32,
    )
    .unwrap_err();
    assert_eq!(e, PipelineError::MissingRead);
}

#[test]
fn pin_interior_memop_is_still_enforced_by_the_ir() {
    // compile_fail twin: a read is not a ComputeStage, .map() rejects it
    let e = Pipeline::new(
        vec![
            IOp::Mem(MemOp::Read { dtype: DType::F32 }),
            IOp::Mem(MemOp::Read { dtype: DType::F32 }),
            IOp::Mem(MemOp::Write { dtype: DType::F32 }),
        ],
        vec![4],
        1,
        DType::F32,
        DType::F32,
    )
    .unwrap_err();
    assert!(matches!(e, PipelineError::InteriorMemOp { index: 1, .. }));
}

#[test]
fn pin_dtype_boundary_is_carried_by_the_ir() {
    // compile_fail twin: write() seals at the chain's current type — the
    // lowered IR records exactly that dtype pair, nothing else
    let p = Chain::read::<CU8>(&[4]).map(Mul(2.0)).cast::<CF32>().write();
    assert_eq!(p.pipeline().dtin, DType::U8);
    assert_eq!(p.pipeline().dtout, DType::F32);
}

// --- engine <-> oracle equivalence for chain-built pipelines ---------------

#[test]
fn chain_built_f64_paths_are_bit_exact_against_the_oracle() {
    // every integer-output / f64 path accumulates in f64: bit-equal to
    // hostref for chains built through the typed front door
    forall(60, |rng| {
        let eng = HostFusedEngine::new();
        let dtin = DTYPES[rng.usize(0, DTYPES.len())];
        let dtout = [DType::U8, DType::U16, DType::I32, DType::F64][rng.usize(0, 4)];
        let k = rng.usize(1, 6);
        let stages: Vec<ComputeOp> = (0..k)
            .map(|_| {
                let op = [Opcode::Mul, Opcode::Add, Opcode::Sub, Opcode::Max][rng.usize(0, 4)];
                ComputeOp::scalar(op, rng.f64(0.5, 1.5))
            })
            .collect();
        let batch = rng.usize(1, 4);
        let p = build_erased(&stages, &[5, 7], batch, dtin, dtout);
        let input = rand_tensor(rng, &[batch, 5, 7], dtin);
        let got = eng.run(&p, &input).unwrap();
        let want = hostref::run_pipeline(&p, &input);
        assert_eq!(got, want, "{dtin}->{dtout} chain of {k}");
    });
}

#[test]
fn chain_built_f32_fast_path_stays_within_epsilon() {
    let eng = HostFusedEngine::new();
    let typed = Chain::read::<CF32>(&[32, 32])
        .batch(2)
        .map(Mul(0.5))
        .map(Sub(3.0))
        .map(Div(1.7))
        .write();
    let mut rng = Rng::new(77);
    let input = Tensor::from_f32(&rng.vec_f32(2 * 32 * 32, -4.0, 4.0), &[2, 32, 32]);
    let got = eng.run(typed.pipeline(), &input).unwrap();
    let want = hostref::run_pipeline(typed.pipeline(), &input);
    for (i, (a, b)) in got.to_f64_vec().iter().zip(want.to_f64_vec()).enumerate() {
        assert!((a - b).abs() <= 1e-4 + 1e-4 * b.abs(), "elem {i}: {a} vs {b}");
    }
}

#[test]
fn typed_run_host_equals_dynamic_dispatch_for_every_dtype_pair() {
    // the monomorphized entry (compile-time lane selection) and the dynamic
    // entry (runtime dtype match) must be the SAME loops — bitwise
    let eng = HostFusedEngine::new();
    let mut rng = Rng::new(9);

    macro_rules! case {
        ($in:ty, $out:ty, $dtin:expr) => {{
            let typed = Chain::read::<$in>(&[6, 5])
                .batch(3)
                .map(Mul(1.3))
                .map(Add(2.0))
                .cast::<$out>()
                .write();
            let input = rand_tensor(&mut rng, &[3, 6, 5], $dtin);
            let mono = typed.run_host(&eng, &input).unwrap();
            let dynamic = eng.run(typed.pipeline(), &input).unwrap();
            assert_eq!(mono, dynamic);
        }};
    }
    case!(CU8, CU8, DType::U8);
    case!(CU8, CF32, DType::U8);
    case!(CF32, CF32, DType::F32);
    case!(CF64, CU8, DType::F64);
    case!(CF64, CF64, DType::F64);
}

#[test]
fn chain_and_untyped_ir_share_one_plan_cache_entry() {
    // signatures are param-agnostic and identical across front doors: one
    // cached plan serves both (the reuse contract of the redesign)
    let eng = HostFusedEngine::new();
    let typed = Chain::read::<CU8>(&[8])
        .map(ConvertTo)
        .map(Mul(0.5))
        .cast::<CF32>()
        .write();
    let untyped = Pipeline::from_opcodes(
        &[(Opcode::Nop, 0.0), (Opcode::Mul, 99.0)],
        &[8],
        1,
        DType::U8,
        DType::F32,
    )
    .unwrap();
    assert_eq!(typed.signature(), fkl::ops::Signature::of(&untyped));
    let x = Tensor::from_u8(&[2; 8], &[1, 8]);
    eng.run(typed.pipeline(), &x).unwrap();
    eng.run(&untyped, &x).unwrap();
    assert_eq!(eng.plan_cache_len(), 1, "both front doors hit one plan");
}
