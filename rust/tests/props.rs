//! Property tests (proplite) over the pure coordination logic — no XLA, so
//! these run thousands of cases quickly.

use std::time::{Duration, Instant};

use fkl::coordinator::{BatchPolicy, Batcher, PendingRequest};
use fkl::fusion::{cost, hfusion};
use fkl::hostref;
use fkl::jsonlite;
use fkl::ops::{Opcode, Pipeline, Signature, ALL_OPCODES};
use fkl::proplite::{forall, Rng};
use fkl::tensor::{DType, Tensor};

#[test]
fn prop_hf_packing_covers_exactly_once() {
    forall(500, |rng| {
        let m = rng.usize(1, 2000);
        let mut buckets: Vec<usize> = (0..rng.usize(1, 6)).map(|_| rng.usize(1, 128)).collect();
        buckets.push(rng.usize(1, 2048).max(m)); // ensure coverage exists
        let launches = hfusion::pack(m, &buckets);
        let assigned: usize = launches.iter().map(|l| l.used).sum();
        assert_eq!(assigned, m, "every request exactly once");
        for l in &launches {
            assert!(l.used <= l.bucket);
            assert!(buckets.contains(&l.bucket));
        }
        // padding only on the last launch
        for l in &launches[..launches.len() - 1] {
            assert_eq!(l.padding(), 0);
        }
    });
}

#[test]
fn prop_signature_ignores_params_only() {
    forall(300, |rng| {
        let k = rng.usize(1, 12);
        let ops: Vec<Opcode> = (0..k).map(|_| *rng.pick(&ALL_OPCODES)).collect();
        let mk = |rng: &mut Rng| {
            let chain: Vec<(Opcode, f64)> =
                ops.iter().map(|&o| (o, rng.f64(-10.0, 10.0))).collect();
            Pipeline::from_opcodes(&chain, &[8, 8], 2, DType::F32, DType::F32).unwrap()
        };
        let a = Signature::of(&mk(rng));
        let b = Signature::of(&mk(rng));
        assert_eq!(a, b, "params must not affect the signature");
    });
}

#[test]
fn prop_hostref_fused_equals_unfused_for_floats() {
    // float chains have no step-boundary saturation: the two semantics agree
    forall(200, |rng| {
        let k = rng.usize(1, 10);
        let safe = [Opcode::Mul, Opcode::Add, Opcode::Sub, Opcode::Min, Opcode::Max, Opcode::Abs];
        let chain: Vec<(Opcode, f64)> =
            (0..k).map(|_| (*rng.pick(&safe), rng.f64(-2.0, 2.0))).collect();
        let p = Pipeline::from_opcodes(&chain, &[4, 4], 2, DType::F64, DType::F64).unwrap();
        let vals: Vec<f64> = (0..32).map(|_| rng.f64(-5.0, 5.0)).collect();
        let x = Tensor::from_f64(&vals, &[2, 4, 4]);
        assert_eq!(hostref::run_pipeline(&p, &x), hostref::run_unfused(&p, &x));
    });
}

#[test]
fn prop_u8_fused_saturates_at_most_once() {
    // invariant: for monotone-increasing chains, fused output >= unfused
    // output can only differ where saturation clipped intermediate steps
    forall(200, |rng| {
        let chain = [(Opcode::Mul, rng.f64(1.0, 3.0)), (Opcode::Sub, rng.f64(0.0, 100.0))];
        let p = Pipeline::from_opcodes(&chain, &[16], 1, DType::U8, DType::U8).unwrap();
        let x = Tensor::from_u8(&rng.vec_u8(16), &[1, 16]);
        let fused = hostref::run_pipeline(&p, &x);
        let unfused = hostref::run_unfused(&p, &x);
        for (f, u) in fused.to_f64_vec().iter().zip(unfused.to_f64_vec()) {
            // intermediate rounding can move the unfused result by <=1.5;
            // saturation can only LOWER the unfused value further
            assert!(*f >= u - 2.0, "single-saturation must not lose value: {f} vs {u}");
        }
    });
}

#[test]
fn prop_jsonlite_roundtrip() {
    forall(300, |rng| {
        let v = random_json(rng, 3);
        let text = v.to_json();
        let parsed = jsonlite::parse(&text).expect("emitted json must parse");
        assert_eq!(parsed, v, "roundtrip");
    });
}

fn random_json(rng: &mut Rng, depth: usize) -> jsonlite::Value {
    use jsonlite::Value;
    let choice = if depth == 0 { rng.usize(0, 4) } else { rng.usize(0, 6) };
    match choice {
        0 => Value::Null,
        1 => Value::Bool(rng.bool()),
        2 => Value::Num((rng.f64(-1e6, 1e6) * 100.0).round() / 100.0),
        3 => {
            let n = rng.usize(0, 8);
            Value::Str((0..n).map(|_| *rng.pick(&['a', 'b', '"', '\\', 'x', '\n'])).collect())
        }
        4 => Value::Arr((0..rng.usize(0, 4)).map(|_| random_json(rng, depth - 1)).collect()),
        _ => Value::Obj(
            (0..rng.usize(0, 4))
                .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                .collect(),
        ),
    }
}

#[test]
fn prop_batcher_conserves_requests() {
    forall(200, |rng| {
        let max_batch = rng.usize(1, 16);
        let mut b = Batcher::new(BatchPolicy {
            max_batch,
            window: Duration::from_millis(rng.range_u64(0, 5)),
            ..Default::default()
        });
        let n = rng.usize(1, 60);
        let n_streams = rng.usize(1, 4);
        for i in 0..n {
            let stream = rng.usize(0, n_streams);
            let p = Pipeline::from_opcodes(
                &[(Opcode::Mul, 1.0)],
                &[stream + 1, 4],
                1,
                DType::F32,
                DType::F32,
            )
            .unwrap();
            let enqueued = Instant::now();
            b.push(PendingRequest {
                pipeline: p,
                item: Tensor::zeros(DType::F32, &[1, stream + 1, 4]),
                enqueued,
                deadline: None,
                reply: i,
                trace_id: 0,
                trace_verdict: 0,
                admitted: enqueued,
            });
        }
        let far_future = Instant::now() + Duration::from_secs(10);
        let mut seen = Vec::new();
        while let Some(g) = b.pop_ready(far_future) {
            assert!(g.expired.is_empty(), "deadline-free requests never expire");
            assert!(g.live.len() <= max_batch);
            // all same stream key within a group
            let key = Signature::of(&g.live[0].pipeline).stream_key();
            for r in &g.live {
                assert_eq!(Signature::of(&r.pipeline).stream_key(), key);
            }
            seen.extend(g.live.iter().map(|r| r.reply));
        }
        seen.sort_unstable();
        assert_eq!(seen, (0..n).collect::<Vec<_>>(), "no loss, no duplication");
    });
}

#[test]
fn prop_cost_model_monotone_in_work() {
    forall(300, |rng| {
        let hw = cost::HwProfile {
            mem_bw: rng.f64(1e9, 1e12),
            flops: rng.f64(1e9, 1e13),
            launch_overhead: rng.f64(1e-7, 1e-4),
        };
        let elems = rng.f64(1e3, 1e8);
        let bytes = elems * rng.f64(1.0, 16.0);
        let i1 = rng.f64(1.0, 1e4);
        let i2 = i1 * rng.f64(1.0, 8.0);
        let t1 = cost::kernel_time(&hw, bytes, elems, i1);
        let t2 = cost::kernel_time(&hw, bytes, elems, i2);
        assert!(t2 >= t1 * 0.999, "more instructions can never be faster");
        // fused never slower than unfused for >=2 identical ops
        let n = rng.usize(2, 64);
        let f = cost::fused_time(&hw, elems, bytes, n as f64);
        let u = cost::unfused_time(&hw, elems, bytes, &vec![1.0; n]);
        assert!(f <= u * 1.001, "fusion must not hurt in the model");
    });
}

#[test]
fn prop_tensor_cast_saturation_bounds() {
    forall(300, |rng| {
        let n = rng.usize(1, 64);
        let vals: Vec<f64> = (0..n).map(|_| rng.f64(-1e4, 1e4)).collect();
        let t = Tensor::from_f64_cast(&vals, &[n], DType::U8);
        for &b in t.as_u8().unwrap() {
            let _ = b; // u8 is definitionally in range — check roundtrip sanity instead
        }
        let back = t.to_f64_vec();
        for (orig, got) in vals.iter().zip(back) {
            assert!((0.0..=255.0).contains(&got));
            if (0.0..=255.0).contains(orig) {
                assert!((orig - got).abs() <= 0.5 + 1e-9, "{orig} -> {got}");
            }
        }
    });
}
