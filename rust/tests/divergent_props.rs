//! Divergent-HF equivalence properties: a mixed window served in ONE pass
//! must be indistinguishable — bitwise — from serving every request alone,
//! under permutation, under embedding of identical-signature subgroups,
//! and at every thread count (extending the PR 4 param-divergence
//! regression to signature divergence).

use fkl::chain::{Add, Chain, CvtColor, Mul, MulC3, F32, F64, U8};
use fkl::exec::{Engine, HostFusedEngine};
use fkl::fusion::{hfusion, DivergentPlan, HostPlan};
use fkl::hostref;
use fkl::ops::{Pipeline, ReduceKind};
use fkl::proplite::{forall, Rng};
use fkl::tensor::{make_frame, DType, Rect, Tensor};

/// A window covering every pipeline family: dense chains (two params, one
/// signature), a lane-structured dense body, a resize→split structured
/// chain, a crop-read reduce and a dense reduce pair.
fn mixed_window(rng: &mut Rng) -> Vec<(Pipeline, Tensor)> {
    let dense_item = Tensor::from_u8(&rng.vec_u8(2 * 48), &[2, 6, 8]);
    let f64_item = Tensor::from_f64(
        &(0..36).map(|_| rng.f64(-3.0, 3.0)).collect::<Vec<_>>(),
        &[1, 4, 3, 3],
    );
    let frame = make_frame(24, 30, rng.usize(1, 100) as u64);
    vec![
        (
            Chain::read::<U8>(&[6, 8])
                .batch(2)
                .map(Mul(1.7))
                .map(Add(3.0))
                .write()
                .into_pipeline(),
            dense_item.clone(),
        ),
        (
            Chain::read::<U8>(&[6, 8])
                .batch(2)
                .map(Mul(0.4))
                .map(Add(-1.0))
                .write()
                .into_pipeline(),
            dense_item.clone(),
        ),
        (
            Chain::read::<F64>(&[4, 3, 3])
                .map(CvtColor)
                .map(MulC3([0.5, 1.5, 2.5]))
                .write()
                .into_pipeline(),
            f64_item,
        ),
        (
            Chain::read_resize::<U8>(Rect::new(2, 3, 14, 9), 7, 5)
                .map(CvtColor)
                .cast::<F32>()
                .write_split()
                .into_pipeline(),
            frame.clone(),
        ),
        (
            Chain::read_crop::<U8>(Rect::new(1, 1, 9, 7))
                .map(Mul(0.5))
                .reduce_per_channel(ReduceKind::Mean)
                .into_pipeline(),
            frame,
        ),
        (
            Chain::read::<U8>(&[6, 8])
                .batch(2)
                .reduce_pair(ReduceKind::Mean, ReduceKind::SumSq)
                .into_pipeline(),
            dense_item,
        ),
    ]
}

fn as_refs(window: &[(Pipeline, Tensor)]) -> Vec<(&Pipeline, &Tensor)> {
    window.iter().map(|(p, t)| (p, t)).collect()
}

#[test]
fn divergent_windows_are_bit_equal_to_per_item_serving() {
    forall(10, |rng| {
        let window = mixed_window(rng);
        let refs = as_refs(&window);
        for threads in [1usize, 2, 8] {
            let eng = HostFusedEngine::with_threads(threads);
            let out = eng.run_divergent(&refs);
            assert_eq!(out.launches, 1, "one pass for the whole window");
            assert!(out.distinct_signatures >= 3);
            for (i, ((p, t), res)) in refs.iter().zip(&out.results).enumerate() {
                let got = res.as_ref().expect("window item serves");
                assert_eq!(got, &eng.run(p, t).unwrap(), "t{threads} item {i} vs per-item");
                assert_eq!(got, &hostref::run_pipeline(p, t), "t{threads} item {i} vs oracle");
            }
        }
    });
}

#[test]
fn divergent_results_are_invariant_under_window_permutation() {
    let mut rng = Rng::new(42);
    let window = mixed_window(&mut rng);
    let refs = as_refs(&window);
    let eng = HostFusedEngine::with_threads(4);
    let base = eng.run_divergent(&refs);
    // rotations and the reversal: every item's result follows the item
    for rot in 1..refs.len() {
        let mut perm: Vec<usize> = (rot..refs.len()).chain(0..rot).collect();
        if rot % 2 == 0 {
            perm.reverse();
        }
        let permuted: Vec<(&Pipeline, &Tensor)> = perm.iter().map(|&i| refs[i]).collect();
        let out = eng.run_divergent(&permuted);
        for (slot, &orig) in perm.iter().enumerate() {
            assert_eq!(
                out.results[slot].as_ref().unwrap(),
                base.results[orig].as_ref().unwrap(),
                "rot {rot}: permuted slot {slot} != original item {orig}"
            );
        }
    }
}

#[test]
fn identical_sig_subgroups_embedded_in_a_mixed_window_keep_their_params() {
    // the PR 4 regression (param-divergent company never inherits the
    // head's params) extended to SIGNATURE divergence: identical-signature
    // subgroups ride inside a mixed window and each request still serves
    // with its own params
    let item = Tensor::from_u8(&[10u8; 100], &[1, 10, 10]);
    let frame = make_frame(16, 16, 5);
    let mk = |mul: f64| {
        Chain::read::<U8>(&[10, 10]).map(Mul(mul)).cast::<F32>().write().into_pipeline()
    };
    let crop = Chain::read_crop::<U8>(Rect::new(0, 0, 4, 4)).write().into_pipeline();
    let a = mk(2.0);
    let b = mk(5.0);
    let c = mk(2.0); // same sig AND params as `a`
    let window: Vec<(&Pipeline, &Tensor)> =
        vec![(&a, &item), (&crop, &frame), (&b, &item), (&c, &item)];
    let eng = HostFusedEngine::with_threads(2);
    let out = eng.run_divergent(&window);
    let at = |i: usize| out.results[i].as_ref().unwrap().as_f32().unwrap()[0];
    assert_eq!(at(0), 20.0, "head subgroup keeps its params");
    assert_eq!(at(2), 50.0, "param-divergent company keeps ITS params");
    assert_eq!(at(3), 20.0, "the embedded identical pair agrees");
    assert_eq!(
        out.results[1].as_ref().unwrap(),
        &hostref::run_pipeline(&crop, &frame),
        "the structured item is untouched by its dense company"
    );
    assert_eq!(out.distinct_signatures, 2);
}

#[test]
fn weighted_chunking_properties() {
    forall(50, |rng| {
        let n = rng.usize(1, 40);
        let weights: Vec<usize> = (0..n).map(|_| rng.usize(0, 5000)).collect();
        let lanes = rng.usize(1, 12);
        let chunks = hfusion::chunk_weighted(&weights, lanes);
        assert!(!chunks.is_empty() && chunks.len() <= lanes.min(n));
        let mut covered = 0usize;
        for r in &chunks {
            assert!(!r.is_empty());
            assert_eq!(r.start, covered, "contiguous, ordered, no overlap");
            covered = r.end;
        }
        assert_eq!(covered, n, "every item assigned exactly once");
        // padding accounting: idle = lanes * max - total
        let lane_w: Vec<usize> =
            chunks.iter().map(|r| weights[r.start..r.end].iter().sum()).collect();
        let max = *lane_w.iter().max().unwrap();
        let total: usize = weights.iter().sum();
        assert_eq!(
            hfusion::chunk_padding(&weights, &chunks),
            chunks.len() * max - total,
            "idle weight is lanes*max - total"
        );
    });
}

#[test]
fn divergent_plan_reuses_the_engine_cache_and_reports_occupancy() {
    let mut rng = Rng::new(7);
    let window = mixed_window(&mut rng);
    let refs = as_refs(&window);
    let eng = HostFusedEngine::with_threads(8);
    let _ = eng.run_divergent(&refs);
    let distinct = 5; // two dense chains share one signature
    assert_eq!(eng.plan_cache_len(), distinct, "sub-plans land in the signature cache");
    // a second window of the same streams compiles nothing new
    let _ = eng.run_divergent(&refs);
    assert_eq!(eng.plan_cache_len(), distinct);
    assert_eq!(eng.divergent_runs(), 2);

    // the standalone planner agrees on the accounting
    let pipes: Vec<&Pipeline> = refs.iter().map(|&(p, _)| p).collect();
    let plan = DivergentPlan::compile(&pipes, 3, |p| std::rc::Rc::new(HostPlan::compile(p)));
    assert_eq!(plan.distinct_signatures(), distinct);
    assert!(plan.is_divergent());
    let total: usize = pipes.iter().map(|p| p.batch * p.item_elems()).sum();
    assert_eq!(plan.total_work_elems(), total);
    assert!(plan.occupancy() > 0.0 && plan.occupancy() <= 1.0);
}

#[test]
fn mixed_dtype_windows_serve_across_the_whole_dtype_table() {
    // five items, five input dtypes, one pass — nothing casts silently
    let mk = |dt: DType| {
        fkl::chain::build_erased_opcodes(
            &[(fkl::ops::Opcode::Mul, 2.0), (fkl::ops::Opcode::Add, 1.0)],
            &[3, 4],
            1,
            dt,
            dt,
        )
    };
    let pipes: Vec<Pipeline> =
        [DType::U8, DType::U16, DType::I32, DType::F32, DType::F64].map(mk).into();
    let vals: Vec<f64> = (0..12).map(|i| i as f64).collect();
    let items: Vec<Tensor> =
        pipes.iter().map(|p| Tensor::from_f64_cast(&vals, &[1, 3, 4], p.dtin)).collect();
    let window: Vec<(&Pipeline, &Tensor)> = pipes.iter().zip(&items).collect();
    let eng = HostFusedEngine::with_threads(2);
    let out = eng.run_divergent(&window);
    assert_eq!(out.distinct_signatures, 5);
    for (i, ((p, t), res)) in window.iter().zip(&out.results).enumerate() {
        assert_eq!(
            res.as_ref().unwrap(),
            &hostref::run_pipeline(p, t),
            "dtype lane {i} is bit-equal"
        );
    }
}
