"""ReduceDPP: one-pass multi-statistic reduction vs oracle."""

import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from compile.kernels import reduce as k_reduce
from compile.kernels import ref as k_ref


@settings(max_examples=20, deadline=None)
@given(
    h=st.sampled_from([1, 8, 64, 128]),
    w=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_reduce_stats_matches_ref(h, w, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.uniform(-50, 50, size=(h, w)), jnp.float32)
    got = np.asarray(k_reduce.make_reduce_stats((h, w), "f32")(x))
    want = np.asarray(k_ref.reduce_stats_ref(x))
    np.testing.assert_allclose(got[:2], want[:2], atol=1e-4)  # max, min exact-ish
    np.testing.assert_allclose(got[2:], want[2:], rtol=1e-4, atol=1e-2)  # sum, mean


def test_tiled_grid_accumulates_across_programs():
    # h=128 with tile 64 -> 2 programs; the second must fold into the first
    x = jnp.concatenate(
        [jnp.full((64, 8), 1.0, jnp.float32), jnp.full((64, 8), 3.0, jnp.float32)]
    )
    got = np.asarray(k_reduce.make_reduce_stats((128, 8), "f32")(x))
    assert got[0] == 3.0 and got[1] == 1.0
    np.testing.assert_allclose(got[2], 64 * 8 * 4.0)
    np.testing.assert_allclose(got[3], 2.0)


def test_negative_only_matrix():
    x = jnp.full((8, 8), -7.5, jnp.float32)
    got = np.asarray(k_reduce.make_reduce_stats((8, 8), "f32")(x))
    assert got[0] == -7.5 and got[1] == -7.5
    np.testing.assert_allclose(got[3], -7.5)
