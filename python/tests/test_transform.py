"""TransformDPP (Pallas, interpret mode) vs the pure-jnp oracle.

This is the core L1 correctness signal: Vertical Fusion must never change
numerics. Hypothesis sweeps shapes, dtypes, batch sizes and op chains.
"""

import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile.kernels import ref as k_ref
from compile.kernels import transform as k_transform
from compile.opcodes import DTYPES, OPS

OP_NAMES = sorted(OPS, key=lambda n: OPS[n][0])


def _rand_input(rng, shape, dtin):
    if dtin in ("u8", "u16"):
        hi = 255 if dtin == "u8" else 4096
        return jnp.asarray(rng.integers(0, hi, size=shape), DTYPES[dtin])
    return jnp.asarray(rng.uniform(-4, 4, size=shape), DTYPES[dtin])


def _tol(dtin, dtout):
    if dtout in ("u8", "u16"):
        return dict(atol=1, rtol=0)
    return dict(atol=1e-4, rtol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    h=st.integers(1, 16),
    w=st.integers(1, 32),
    batch=st.integers(1, 4),
    ops=st.lists(st.sampled_from(OP_NAMES), min_size=1, max_size=8),
    dtin=st.sampled_from(["u8", "f32", "f64"]),
    dtout=st.sampled_from(["u8", "f32", "f64"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_chain_matches_ref(h, w, batch, ops, dtin, dtout, seed):
    rng = np.random.default_rng(seed)
    x = _rand_input(rng, (batch, h, w), dtin)
    params = jnp.asarray(rng.uniform(0.5, 2.0, size=(len(ops),)), jnp.float32)
    f = k_transform.make_chain(ops, (h, w), batch, dtin, dtout)
    got = f(x, params)
    want = k_ref.chain_ref(x, params, ops, dtin, dtout)
    assert got.dtype == want.dtype and got.shape == want.shape
    np.testing.assert_allclose(np.asarray(got, np.float64), np.asarray(want, np.float64), **_tol(dtin, dtout))


@settings(max_examples=15, deadline=None)
@given(
    iters=st.integers(0, 50),
    batch=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
)
def test_staticloop_matches_ref(iters, batch, seed):
    rng = np.random.default_rng(seed)
    ops = ["mul", "add"]
    x = jnp.asarray(rng.uniform(0, 1, size=(batch, 6, 10)), jnp.float32)
    # keep the loop contractive so 50 iterations stay finite
    params = jnp.asarray([0.9, 0.05], jnp.float32)
    f = k_transform.make_staticloop(ops, (6, 10), batch, "f32", "f32")
    got = f(jnp.asarray([iters], jnp.int32), x, params)
    want = k_ref.staticloop_ref(x, params, iters, ops, "f32", "f32")
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)


def test_staticloop_zero_iters_is_io_cast_only():
    x = jnp.asarray(np.arange(24).reshape(1, 4, 6), jnp.uint8)
    f = k_transform.make_staticloop(["mul"], (4, 6), 1, "u8", "u8")
    got = f(jnp.asarray([0], jnp.int32), x, jnp.asarray([3.0], jnp.float32))
    np.testing.assert_array_equal(got, x)


def test_chain_channel_params_broadcast():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.uniform(0, 1, size=(2, 5, 7, 3)), jnp.float32)
    ops = ["mul", "sub"]
    params = jnp.asarray(rng.uniform(0.5, 1.5, size=(2, 3)), jnp.float32)
    f = k_transform.make_chain(ops, (5, 7, 3), 2, "f32", "f32", channel_params=True)
    got = f(x, params)
    want = (x * params[0]) - params[1]
    np.testing.assert_allclose(got, want, atol=1e-6)


def test_tiled_variant_matches_flat():
    """Row-tiled HBM<->VMEM schedule must be numerically identical."""
    rng = np.random.default_rng(1)
    h, w = 64, 48  # h % 32 == 0 -> real tiling kicks in
    x = jnp.asarray(rng.uniform(-2, 2, size=(2, h, w)), jnp.float32)
    ops = ["mul", "add", "abs"]
    params = jnp.asarray([1.5, -0.3, 0.0], jnp.float32)
    flat = k_transform.make_chain(ops, (h, w), 2, "f32", "f32")(x, params)
    tiled = k_transform.make_chain_tiled(ops, (h, w), 2, "f32", "f32")(x, params)
    np.testing.assert_array_equal(np.asarray(flat), np.asarray(tiled))


def test_hf_batch_isolation():
    """HF invariant (paper Fig. 5): each batch plane only sees its own data."""
    x = np.zeros((3, 4, 4), np.float32)
    x[1] = 100.0
    f = k_transform.make_chain(["mul"], (4, 4), 3, "f32", "f32")
    got = np.asarray(f(jnp.asarray(x), jnp.asarray([2.0], jnp.float32)))
    assert (got[0] == 0).all() and (got[1] == 200.0).all() and (got[2] == 0).all()


@pytest.mark.parametrize("dtin,dtout", [("u8", "u8"), ("f32", "u8"), ("u8", "f32")])
def test_saturating_write(dtin, dtout):
    """WriteOp boundary must saturate like OpenCV's convertTo (paper wrappers)."""
    x = jnp.asarray(np.full((1, 2, 2), 200), DTYPES[dtin])
    f = k_transform.make_chain(["mul"], (2, 2), 1, dtin, dtout)
    got = np.asarray(f(x, jnp.asarray([2.0], jnp.float32)))
    if dtout == "u8":
        assert (got == 255).all()
    else:
        assert (got == 400.0).all()


def test_vmem_footprint_estimate():
    fp = k_transform.vmem_footprint_bytes(["mul"] * 100, (32, 4096), "f32", "f32", tiled=True)
    # footprint is chain-length independent and fits VMEM with headroom
    assert fp == 32 * 4096 * 12
    assert fp < 16 * 2**20 / 4
