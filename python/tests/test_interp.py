"""InterpDPP: the runtime-fusion kernel must match both its oracle and the
directly-traced chain for every opcode in the vocabulary."""

import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from compile.kernels import interp as k_interp
from compile.kernels import ref as k_ref
from compile.kernels import transform as k_transform
from compile.opcodes import N_OPS, OPS

OP_NAMES = sorted(OPS, key=lambda n: OPS[n][0])


def _encode(chain, kmax):
    opc = np.zeros(kmax, np.int32)
    par = np.zeros(kmax, np.float32)
    for i, (name, p) in enumerate(chain):
        opc[i] = OPS[name][0]
        par[i] = p
    return jnp.asarray(opc), jnp.asarray(par)


@settings(max_examples=25, deadline=None)
@given(
    chain=st.lists(
        st.tuples(st.sampled_from(OP_NAMES), st.floats(0.25, 2.0)),
        min_size=1,
        max_size=12,
    ),
    seed=st.integers(0, 2**31 - 1),
)
def test_interp_matches_direct_chain(chain, seed):
    kmax = 16
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.uniform(-2, 2, size=(2, 6, 8)), jnp.float32)
    opc, par = _encode(chain, kmax)

    f = k_interp.make_interp(kmax, (6, 8), 2, "f32", "f32")
    got = f(x, opc, par)

    ops = [c[0] for c in chain]
    params = jnp.asarray([c[1] for c in chain], jnp.float32)
    direct = k_transform.make_chain(ops, (6, 8), 2, "f32", "f32")(x, params)
    np.testing.assert_allclose(np.asarray(got), np.asarray(direct), atol=1e-4, rtol=1e-4)


def test_all_nops_is_identity():
    kmax = 16
    x = jnp.asarray(np.arange(48, dtype=np.float32).reshape(1, 6, 8))
    f = k_interp.make_interp(kmax, (6, 8), 1, "f32", "f32")
    got = f(x, jnp.zeros(kmax, jnp.int32), jnp.zeros(kmax, jnp.float32))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(x))


def test_out_of_range_opcode_is_clamped_not_crashed():
    kmax = 4
    x = jnp.ones((1, 2, 2), jnp.float32)
    opc = jnp.asarray([999, -5, 0, 0], jnp.int32)
    par = jnp.zeros(4, jnp.float32)
    f = k_interp.make_interp(kmax, (2, 2), 1, "f32", "f32")
    out = f(x, opc, par)
    assert np.isfinite(np.asarray(out)).all()


def test_interp_matches_ref_oracle():
    kmax = 16
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.uniform(0, 1, size=(1, 4, 4)), jnp.float32)
    chain = [("mul", 2.0), ("add", 0.5), ("abs", 0.0), ("min", 1.2)]
    opc, par = _encode(chain, kmax)
    got = k_interp.make_interp(kmax, (4, 4), 1, "f32", "f32")(x, opc, par)
    want = k_ref.interp_ref(x[0], opc, par)
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want), atol=1e-5)


def test_vocabulary_is_dense():
    codes = sorted(OPS[n][0] for n in OPS)
    assert codes == list(range(N_OPS)), "switch table must be dense"
