"""PreprocDPP (the paper's production pipeline) vs the jnp oracle, plus the
unfused single-step vocabulary."""

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from compile.kernels import preproc as k_preproc
from compile.kernels import ref as k_ref


def _frame(rng, h=96, w=160):
    return jnp.asarray(rng.integers(0, 256, size=(h, w, 3)), jnp.uint8)


@settings(max_examples=10, deadline=None)
@given(batch=st.integers(1, 4), seed=st.integers(0, 2**31 - 1))
def test_preproc_kernel_matches_ref(batch, seed):
    rng = np.random.default_rng(seed)
    frame = _frame(rng)
    rects = []
    for _ in range(batch):
        w = int(rng.integers(8, 40))
        h = int(rng.integers(8, 30))
        x0 = int(rng.integers(0, 160 - w))
        y0 = int(rng.integers(0, 96 - h))
        rects.append([x0, y0, w, h])
    rects = jnp.asarray(rects, jnp.int32)
    mulv = jnp.asarray(rng.uniform(0.5, 1.5, 3), jnp.float32)
    subv = jnp.asarray(rng.uniform(0, 1, 3), jnp.float32)
    divv = jnp.asarray(rng.uniform(0.5, 2, 3), jnp.float32)

    dh, dw = 16, 12
    f = k_preproc.make_preproc((96, 160, 3), batch, dh, dw)
    got = f(frame, rects, mulv, subv, divv)
    want = k_ref.preproc_ref(frame, rects, mulv, subv, divv, dh, dw)
    assert got.shape == (batch, 3, dh, dw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-3, rtol=1e-3)


def test_identity_resize_recovers_crop():
    rng = np.random.default_rng(1)
    frame = _frame(rng)
    # crop 12x16 resized to 16(h) x 12(w): use crop (w=12,h=16) -> dst (16,12)
    rects = jnp.asarray([[10, 20, 12, 16]], jnp.int32)
    one = jnp.ones(3, jnp.float32)
    zero = jnp.zeros(3, jnp.float32)
    f = k_preproc.make_preproc((96, 160, 3), 1, 16, 12)
    got = np.asarray(f(frame, rects, one, zero, one))
    crop = np.asarray(frame)[20:36, 10:22, :].astype(np.float32)
    want = np.transpose(crop[:, :, ::-1], (2, 0, 1))[None]
    np.testing.assert_allclose(got, want, atol=1e-3)


def test_batch_planes_are_independent():
    rng = np.random.default_rng(2)
    frame = _frame(rng)
    rects = jnp.asarray([[0, 0, 20, 20], [100, 40, 20, 20]], jnp.int32)
    one = jnp.ones(3, jnp.float32)
    zero = jnp.zeros(3, jnp.float32)
    f2 = k_preproc.make_preproc((96, 160, 3), 2, 8, 8)
    both = np.asarray(f2(frame, rects, one, zero, one))
    f1 = k_preproc.make_preproc((96, 160, 3), 1, 8, 8)
    a = np.asarray(f1(frame, rects[:1], one, zero, one))
    b = np.asarray(f1(frame, rects[1:], one, zero, one))
    np.testing.assert_allclose(both[0], a[0], atol=1e-5)
    np.testing.assert_allclose(both[1], b[0], atol=1e-5)


def test_single_step_vocabulary_composes_to_fused():
    """Running the unfused step functions in sequence must equal the fused
    kernel (this is the invariant the whole paper rests on)."""
    rng = np.random.default_rng(3)
    frame = _frame(rng)
    x0, y0, w, h = 30, 10, 24, 18
    dh, dw = 12, 10
    steps = k_preproc.make_single_steps(dh, dw, h, w)
    crop = jax.lax.dynamic_slice(frame, (y0, x0, 0), (h, w, 3))
    v = steps["convert"](crop)
    v = steps["resize"](v)
    v = steps["cvtcolor"](v)
    v = steps["mulc"](v, jnp.asarray([1.1, 1.0, 0.9], jnp.float32))
    v = steps["subc"](v, jnp.asarray([0.1, 0.2, 0.3], jnp.float32))
    v = steps["divc"](v, jnp.asarray([2.0, 2.0, 2.0], jnp.float32))
    stepwise = steps["split"](v)

    fused = k_preproc.make_preproc((96, 160, 3), 1, dh, dw)(
        frame,
        jnp.asarray([[x0, y0, w, h]], jnp.int32),
        jnp.asarray([1.1, 1.0, 0.9], jnp.float32),
        jnp.asarray([0.1, 0.2, 0.3], jnp.float32),
        jnp.asarray([2.0, 2.0, 2.0], jnp.float32),
    )
    np.testing.assert_allclose(np.asarray(fused[0]), np.asarray(stepwise), atol=1e-3)
