import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax

# f64 chains (Fig. 23 dtype combos) need real double support.
jax.config.update("jax_enable_x64", True)
