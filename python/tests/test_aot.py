"""AOT driver: manifest schema, HLO-text validity, family coverage."""

import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
ARTIFACTS = os.path.join(REPO, "artifacts")


def test_aot_builds_selected_artifact(tmp_path):
    env = dict(os.environ)
    r = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(tmp_path), "--only", "chain_mul-add_f32"],
        cwd=os.path.join(REPO, "python"),
        capture_output=True,
        text=True,
        env=env,
        timeout=300,
    )
    assert r.returncode == 0, r.stderr
    manifest = json.load(open(tmp_path / "manifest.json"))
    names = [a["name"] for a in manifest["artifacts"]]
    assert "chain_mul-add_f322f32_4x8_b2_pallas" in names
    hlo = (tmp_path / "chain_mul-add_f322f32_4x8_b2_pallas.hlo.txt").read_text()
    assert hlo.startswith("HloModule"), "interchange format must be HLO text"
    # single-output plain-array root (return_tuple=False): entry layout has
    # no tuple in the result type
    assert "->f32[2,4,8]" in hlo.replace(" ", ""), hlo.splitlines()[0]


@pytest.mark.skipif(not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
                    reason="run `make artifacts` first")
def test_manifest_covers_every_experiment():
    m = json.load(open(os.path.join(ARTIFACTS, "manifest.json")))
    arts = m["artifacts"]
    kinds = {a["kind"] for a in arts}
    assert {"chain", "single_op", "staticloop", "interp", "preproc", "preproc_step", "reduce"} <= kinds
    # geometry block drives the Rust experiment sweeps
    g = m["geometry"]
    for key in ("vf_shape", "vec_n", "sizes", "hf_batches", "preproc_batches", "dtype_combos"):
        assert key in g, key
    # every HF bucket has its chain artifact
    for b in g["hf_batches"]:
        assert any(
            a["kind"] == "chain" and a["batch"] == b and a["dtin"] == "u8" for a in arts
        ), f"missing HF bucket {b}"
    # every preproc batch bucket
    for b in g["preproc_batches"]:
        assert any(a["kind"] == "preproc" and a["batch"] == b for a in arts), b
    # every declared file exists and is HLO text
    for a in arts:
        p = os.path.join(ARTIFACTS, a["file"])
        assert os.path.exists(p), a["name"]


@pytest.mark.skipif(not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
                    reason="run `make artifacts` first")
def test_opcode_table_matches_python():
    from compile.opcodes import OPS

    m = json.load(open(os.path.join(ARTIFACTS, "manifest.json")))
    assert m["opcodes"] == {k: v[0] for k, v in OPS.items()}
