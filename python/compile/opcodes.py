"""Shared operation vocabulary for the FKL reproduction.

This is the single source of truth for the element-wise Compute Operation
(COp) vocabulary (paper §IV-A: Unary/Binary Operations). The Rust layer-3
coordinator mirrors this table in ``rust/src/ops/opcodes.rs``; the generated
``artifacts/manifest.json`` embeds it so the Rust registry can assert
consistency at load time (no silent drift between layers).

Opcode numbering is load-bearing: the generic interpreter kernel
(``kernels/interp.py``) receives opcodes as a runtime i32 tensor and branches
with ``lax.switch``, so the order here IS the switch table.
"""

from __future__ import annotations

import jax.numpy as jnp

# name -> (opcode, takes_param)
# Binary ops (paper: BOp) consume a scalar parameter; unary ops (UOp) ignore it.
OPS: dict[str, tuple[int, bool]] = {
    "nop": (0, False),  # identity; also the Cast placeholder (casts happen at the
    #                     read/write boundary, in the compute domain cast == nop)
    "add": (1, True),
    "sub": (2, True),
    "mul": (3, True),
    "div": (4, True),
    "abs": (5, False),
    "neg": (6, False),
    "min": (7, True),
    "max": (8, True),
    "sqrt": (9, False),
    "exp": (10, False),
    "log": (11, False),
    "clamp01": (12, False),
}

N_OPS = len(OPS)

# dtype name -> jnp dtype. These are the I/O dtypes of the Memory Operations
# (paper: ROp/WOp); compute always happens in f32 (or f64 when either end is
# f64), mirroring how integer image ops saturate through a wider type.
DTYPES = {
    "u8": jnp.uint8,
    "u16": jnp.uint16,
    "i32": jnp.int32,
    "f32": jnp.float32,
    "f64": jnp.float64,
}

INT_DTYPES = {"u8": 255.0, "u16": 65535.0, "i32": None}


def compute_dtype(dtin: str, dtout: str):
    """Compute domain for a chain: widest float that covers both ends."""
    if "f64" in (dtin, dtout):
        return jnp.float64
    return jnp.float32


def apply_op(name: str, x, p):
    """Apply one COp in the compute domain. ``p`` is a scalar (traced)."""
    if name == "nop":
        return x
    if name == "add":
        return x + p
    if name == "sub":
        return x - p
    if name == "mul":
        return x * p
    if name == "div":
        return x / p
    if name == "abs":
        return jnp.abs(x)
    if name == "neg":
        return -x
    if name == "min":
        return jnp.minimum(x, p)
    if name == "max":
        return jnp.maximum(x, p)
    if name == "sqrt":
        return jnp.sqrt(jnp.abs(x))
    if name == "exp":
        return jnp.exp(x)
    if name == "log":
        return jnp.log(jnp.abs(x) + 1.0)
    if name == "clamp01":
        return jnp.clip(x, 0.0, 1.0)
    raise ValueError(f"unknown op {name!r}")


def cast_in(x, dtin: str, dtout: str):
    """ReadOp boundary: load from the I/O dtype into the compute domain."""
    return x.astype(compute_dtype(dtin, dtout))


def cast_out(x, dtin: str, dtout: str):
    """WriteOp boundary: saturate back to the output dtype (paper: saturating
    stores for 8/16-bit image types, like OpenCV's convertTo)."""
    if dtout in INT_DTYPES:
        hi = INT_DTYPES[dtout]
        x = jnp.round(x)
        if hi is not None:
            x = jnp.clip(x, 0.0, hi)
    return x.astype(DTYPES[dtout])


def switch_branches():
    """The lax.switch table for the interpreter kernel, in opcode order."""
    names = sorted(OPS, key=lambda n: OPS[n][0])
    return [(lambda n: (lambda x, p: apply_op(n, x, p)))(n) for n in names]
