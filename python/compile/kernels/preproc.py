"""PreprocDPP — the paper's production pipeline as one fused Pallas kernel.

Fig. 25 workload: Batch(Crop -> Resize -> ColorConvert -> Multiply ->
Subtract -> Divide -> Split). This is the kernel AutomaticTV runs in
production per the paper; it exercises every Op class at once:

* Crop + bilinear Resize  — a non-trivial ReadOp (gather pattern, Fig. 11)
* ColorConvert            — a UnaryOp (channel swizzle)
* Mul/Sub/Div             — BinaryOps with per-channel (float3) params
* Split                   — a WriteOp (packed -> planar layout, Fig. 11)

HF is the grid batch axis: one program per crop (the paper's blockIdx.z
plane); each program gathers its own ROI from the shared source frame, so a
whole batch of differently-cropped, differently-sized regions is served by a
single launch — this is the paper's BatchRead with per-plane params.

On a TPU the frame would sit in HBM with dynamic-slice gathers; under
interpret=True the full-frame ref load is exact and cheap on CPU.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from compile.kernels.ref import bilinear_gather


def make_preproc(frame_shape, batch, dh, dw):
    """Build the fused preprocessing kernel.

    Returns ``f(frame, rects, mulv, subv, divv) -> f32[batch, 3, dh, dw]``
    with frame: u8[H, W, 3], rects: i32[batch, 4] (x0, y0, w, h),
    mulv/subv/divv: f32[3].
    """
    fh, fw, _ = frame_shape

    def kernel(frame_ref, rect_ref, mul_ref, sub_ref, div_ref, o_ref):
        frame = frame_ref[...].astype(jnp.float32)  # ReadOp source
        x0, y0 = rect_ref[0, 0], rect_ref[0, 1]
        w, h = rect_ref[0, 2], rect_ref[0, 3]
        # Crop + Resize: bilinear gather of this program's ROI
        img = bilinear_gather(frame, x0, y0, w, h, dh, dw)  # (dh, dw, 3)
        # ColorConvert: RGB <-> BGR
        img = img[:, :, ::-1]
        # Mul / Sub / Div with float3 params
        img = (img * mul_ref[...] - sub_ref[...]) / div_ref[...]
        # Split WOp: packed (dh, dw, 3) -> planar (3, dh, dw)
        o_ref[...] = jnp.transpose(img, (2, 0, 1))[None]

    def f(frame, rects, mulv, subv, divv):
        return pl.pallas_call(
            kernel,
            grid=(batch,),
            in_specs=[
                pl.BlockSpec((fh, fw, 3), lambda b: (0, 0, 0)),
                pl.BlockSpec((1, 4), lambda b: (b, 0)),
                pl.BlockSpec((3,), lambda b: (0,)),
                pl.BlockSpec((3,), lambda b: (0,)),
                pl.BlockSpec((3,), lambda b: (0,)),
            ],
            out_specs=pl.BlockSpec((1, 3, dh, dw), lambda b: (b, 0, 0, 0)),
            out_shape=jax.ShapeDtypeStruct((batch, 3, dh, dw), jnp.float32),
            interpret=True,
        )(frame, rects, mulv, subv, divv)

    return f


def make_single_steps(dh, dw, src_h, src_w):
    """The UNFUSED baseline vocabulary for the same pipeline — one jax fn per
    library call, exactly how OpenCV-CUDA/NPP structure it (paper Fig. 25,
    top halves). Each returns a separately-AOT'd executable, so running the
    pipeline costs one dispatch + one full memory pass per step.

    Returns dict of name -> (fn, arg specs builder handled in model.py).
    """

    def convert(x):  # u8 HWC -> f32 HWC   (cv::convertTo / nppiConvert)
        return x.astype(jnp.float32)

    def resize(x):  # f32 (src_h,src_w,3) -> f32 (dh,dw,3)
        h = jnp.int32(src_h)
        w = jnp.int32(src_w)
        return bilinear_gather(x, jnp.int32(0), jnp.int32(0), w, h, dh, dw)

    def cvtcolor(x):  # BGR<->RGB
        return x[:, :, ::-1]

    def mulc(x, v):
        return x * v

    def subc(x, v):
        return x - v

    def divc(x, v):
        return x / v

    def split(x):  # packed -> planar
        return jnp.transpose(x, (2, 0, 1))

    return {
        "convert": convert,
        "resize": resize,
        "cvtcolor": cvtcolor,
        "mulc": mulc,
        "subc": subc,
        "divc": divc,
        "split": split,
    }
