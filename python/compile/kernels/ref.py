"""Pure-jnp oracles for every Pallas kernel in this package.

These are the correctness baseline: pytest asserts each Pallas kernel (run in
interpret mode) matches its oracle to tight tolerances. They are also lowered
to HLO as the ``variant == "xla"`` artifact family, used by the Rust planner
ablation (Pallas-structured vs XLA-auto-fused lowering of the same chain).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from compile.opcodes import apply_op, cast_in, cast_out


def chain_ref(x, params, ops, dtin, dtout):
    """Fused chain semantics: one read, ops applied in order, one write.

    ``params[i]`` is the scalar parameter of ``ops[i]`` (ignored by unary ops).
    """
    v = cast_in(x, dtin, dtout)
    for i, name in enumerate(ops):
        v = apply_op(name, v, params[i].astype(v.dtype))
    return cast_out(v, dtin, dtout)


def staticloop_ref(x, params, iters, ops, dtin, dtout):
    """Paper's StaticLoop: the op chain body repeated ``iters`` times without
    re-touching memory. ``iters`` is a runtime scalar."""
    v = cast_in(x, dtin, dtout)
    ps = params.astype(v.dtype)

    def body(_, v):
        for i, name in enumerate(ops):
            v = apply_op(name, v, ps[i])
        return v

    v = lax.fori_loop(0, iters, body, v)
    return cast_out(v, dtin, dtout)


def interp_ref(x, opcodes, params):
    """Interpreter semantics (f32 domain): apply opcodes[i] with params[i]."""
    from compile.opcodes import switch_branches

    branches = switch_branches()

    def body(i, v):
        return lax.switch(jnp.clip(opcodes[i], 0, len(branches) - 1), branches, v, params[i])

    return lax.fori_loop(0, opcodes.shape[0], body, x)


def reduce_stats_ref(x):
    """One-pass multi-statistic reduction (paper §IV-C ReduceDPP example:
    max, min, sum and mean of a matrix reading the source once)."""
    xf = x.astype(jnp.float32)
    s = jnp.sum(xf)
    return jnp.stack([jnp.max(xf), jnp.min(xf), s, s / xf.size])


def bilinear_gather(frame_f32, x0, y0, w, h, dh, dw):
    """Sample a (h, w) crop of ``frame_f32`` (H, W, C) to (dh, dw, C) with
    bilinear interpolation, half-pixel centers (matches cv2.resize LINEAR).

    x0/y0/w/h are runtime scalars (i32); dh/dw are static.
    """
    H, W = frame_f32.shape[0], frame_f32.shape[1]
    sy = h.astype(jnp.float32) / dh
    sx = w.astype(jnp.float32) / dw
    dy = (jnp.arange(dh, dtype=jnp.float32) + 0.5) * sy - 0.5
    dx = (jnp.arange(dw, dtype=jnp.float32) + 0.5) * sx - 0.5
    fy = jnp.clip(dy, 0.0, h.astype(jnp.float32) - 1.0)
    fx = jnp.clip(dx, 0.0, w.astype(jnp.float32) - 1.0)
    y0i = jnp.floor(fy).astype(jnp.int32)
    x0i = jnp.floor(fx).astype(jnp.int32)
    y1i = jnp.minimum(y0i + 1, h - 1)
    x1i = jnp.minimum(x0i + 1, w - 1)
    wy = (fy - y0i.astype(jnp.float32))[:, None, None]
    wx = (fx - x0i.astype(jnp.float32))[None, :, None]

    def at(yi, xi):
        yy = jnp.clip(y0 + yi, 0, H - 1)
        xx = jnp.clip(x0 + xi, 0, W - 1)
        return frame_f32[yy[:, None], xx[None, :], :]

    p00 = at(y0i, x0i)
    p01 = at(y0i, x1i)
    p10 = at(y1i, x0i)
    p11 = at(y1i, x1i)
    top = p00 * (1 - wx) + p01 * wx
    bot = p10 * (1 - wx) + p11 * wx
    return top * (1 - wy) + bot * wy


def preproc_ref(frame, rects, mulv, subv, divv, dh, dw):
    """The paper's production pipeline (Fig. 25):
    Batch(Crop -> Resize -> ColorConvert -> Mul -> Sub -> Div -> Split).

    frame: u8 [H, W, 3]; rects: i32 [B, 4] as (x0, y0, w, h);
    mulv/subv/divv: f32 [3]; output planar f32 [B, 3, dh, dw] (the Split WOp).
    """
    frame_f = frame.astype(jnp.float32)

    def one(rect):
        x0, y0, w, h = rect[0], rect[1], rect[2], rect[3]
        img = bilinear_gather(frame_f, x0, y0, w, h, dh, dw)  # (dh, dw, 3)
        img = img[:, :, ::-1]  # ColorConvert: RGB<->BGR swizzle
        img = (img * mulv - subv) / divv
        return jnp.transpose(img, (2, 0, 1))  # Split: packed -> planar

    return jax.vmap(one)(rects)


def resize_ref(img_f32, dh, dw):
    """Whole-image bilinear resize oracle (single-op NPP/OpenCV baseline)."""
    h = jnp.int32(img_f32.shape[0])
    w = jnp.int32(img_f32.shape[1])
    return bilinear_gather(img_f32, jnp.int32(0), jnp.int32(0), w, h, dh, dw)
