"""TransformDPP — the paper's central Data Parallel Pattern as a Pallas kernel.

The DPP (paper §IV-C, Fig. 13) owns thread behaviour: each grid program reads
one block from HBM into VMEM, applies the *entire fused op chain* on
VMEM-resident values (the register-residency analog of paper Fig. 3B / §IV),
and writes once. The chain itself is data (a list of op names baked at trace
time = the paper's template-parameter pack), so ANY user chain lowers into
one kernel — this is Vertical Fusion.

Horizontal Fusion (paper §IV-B BatchRead/BatchWrite, Fig. 12) is the leading
batch axis: grid dimension 0 is the batch plane (the paper's ``blockIdx.z``),
and each program's index_map selects its own image — one launch for B inputs.

Hardware adaptation (DESIGN.md §2): on a real TPU the BlockSpecs below tile
(batch, rows) so that in-block + out-block fit VMEM with double-buffering
headroom; we run under ``interpret=True`` because the CPU PJRT plugin cannot
execute Mosaic custom-calls. Numerics are identical between the two paths.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from compile.opcodes import DTYPES, apply_op, cast_in, cast_out

# Row-tile height used when a single image is tall enough to tile. Chosen so a
# (ROWS_PER_TILE x 4096) f32 in+out block pair stays ~= 256 KiB — far inside a
# TPU core's ~16 MiB VMEM, leaving >30x headroom for double buffering.
ROWS_PER_TILE = 32


def _chain_body(ops, dtin, dtout, n_params_axes):
    """Build the kernel body applying ``ops`` with params from a ref.

    n_params_axes == 1: params[i] scalar per op; == 2: params[i, :] length-3
    channel vector per op (broadcast over the trailing channel axis).
    """

    def kernel(x_ref, p_ref, o_ref):
        v = cast_in(x_ref[...], dtin, dtout)
        for i, name in enumerate(ops):
            if n_params_axes == 1:
                p = p_ref[i].astype(v.dtype)
            else:
                p = p_ref[i, :].astype(v.dtype)  # broadcasts over channels
            v = apply_op(name, v, p)
        o_ref[...] = cast_out(v, dtin, dtout)

    return kernel


def make_chain(ops, shape, batch, dtin, dtout, channel_params=False):
    """Fused-chain TransformDPP.

    Returns ``f(x, params) -> y`` with x: dtin[batch, *shape],
    params: f32[K] (or f32[K, 3] when ``channel_params``), y: dtout[batch, *shape].

    PERF (EXPERIMENTS.md §Perf L1): on the CPU-PJRT substrate the kernel runs
    as ONE whole-array program. An earlier revision used grid=(batch,) with
    per-plane BlockSpecs — the natural TPU schedule — but interpret-mode
    lowering turns each grid step into dynamic-slice + dynamic-update-slice
    of the full array, serializing planes and copying the output per plane
    (16.4ms vs 1.1ms for the CMSD f32 b50 chain). The per-plane HBM<->VMEM
    schedule survives in :func:`make_chain_tiled` (structure tests + the TPU
    mapping documented in DESIGN.md §2); numerics are identical.
    """
    kernel = _chain_body(ops, dtin, dtout, 2 if channel_params else 1)

    def f(x, params):
        return pl.pallas_call(
            kernel,
            out_shape=jax.ShapeDtypeStruct((batch,) + tuple(shape), DTYPES[dtout]),
            interpret=True,
        )(x, params)

    return f


def make_staticloop(ops, shape, batch, dtin, dtout):
    """StaticLoop TransformDPP (paper §VI-B): the chain body repeated a
    *runtime* number of times, keeping the value in registers throughout.

    The paper uses a StaticLoop Op so 19,902 fused operations do not consume
    kernel parameter space; here the trip count is a runtime i32[1] input so a
    single AOT artifact covers the entire VF sweep.

    Returns ``f(iters, x, params) -> y``.
    """
    k = len(ops)

    def kernel(n_ref, x_ref, p_ref, o_ref):
        v = cast_in(x_ref[...], dtin, dtout)
        ps = [p_ref[i].astype(v.dtype) for i in range(k)]

        def body(_, v):
            for name, p in zip(ops, ps):
                v = apply_op(name, v, p)
            return v

        v = lax.fori_loop(0, n_ref[0], body, v)
        o_ref[...] = cast_out(v, dtin, dtout)

    # whole-array single program (see make_chain PERF note)
    def f(iters, x, params):
        return pl.pallas_call(
            kernel,
            out_shape=jax.ShapeDtypeStruct((batch,) + tuple(shape), DTYPES[dtout]),
            interpret=True,
        )(iters, x, params)

    return f


def make_chain_tiled(ops, shape, batch, dtin, dtout):
    """Row-tiled variant of :func:`make_chain` for large single images.

    Demonstrates the HBM<->VMEM BlockSpec schedule a real TPU would use
    (grid = (batch, row_tiles)); used by the L1 structure tests and the
    block-shape perf ablation. Requires shape == (H, W) with H % tile == 0.
    """
    h, w = shape
    tile = ROWS_PER_TILE if h % ROWS_PER_TILE == 0 else 1
    kernel = _chain_body(ops, dtin, dtout, 1)
    k = len(ops)

    def f(x, params):
        return pl.pallas_call(
            kernel,
            grid=(batch, h // tile),
            in_specs=[
                pl.BlockSpec((1, tile, w), lambda b, r: (b, r, 0)),
                pl.BlockSpec((k,), lambda b, r: (0,)),
            ],
            out_specs=pl.BlockSpec((1, tile, w), lambda b, r: (b, r, 0)),
            out_shape=jax.ShapeDtypeStruct((batch, h, w), DTYPES[dtout]),
            interpret=True,
        )(x, params)

    return f


def vmem_footprint_bytes(ops, shape, dtin, dtout, tiled=False):
    """Static VMEM estimate for one program of the TransformDPP (DESIGN.md §8).

    in-block + out-block + one live compute value; the op chain adds no
    footprint because every op is applied value-to-value in registers.
    """
    import numpy as np

    if tiled and len(shape) == 2:
        h, w = shape
        tile = ROWS_PER_TILE if h % ROWS_PER_TILE == 0 else 1
        elems = tile * w
    else:
        elems = int(np.prod(shape))
    in_b = elems * jnp.dtype(DTYPES[dtin]).itemsize
    out_b = elems * jnp.dtype(DTYPES[dtout]).itemsize
    compute_b = elems * (8 if "f64" in (dtin, dtout) else 4)
    return in_b + out_b + compute_b
