"""ReduceDPP — the paper's second Data Parallel Pattern (§IV-C).

The paper's motivating example: "with a ReduceDPP ... for a given matrix we
may find the maximum value, the minimum value, the addition of all the
elements, and the mean value, all by reading the source data only once."

This kernel does exactly that: the grid walks row tiles; each program folds
its tile into four accumulators held in the output block (max, min, sum,
count-scaled mean). Sequential-grid accumulation is the interpret/TPU-safe
revision of a tree reduction: Pallas guarantees grid-order execution on TPU,
so read-modify-write of the out block across programs is well defined.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from compile.opcodes import DTYPES


def make_reduce_stats(shape, dtin, tile_rows=64):
    """One-pass (max, min, sum, mean) over a 2-D matrix.

    Returns ``f(x) -> f32[4]``. Input x: dtin[H, W].
    """
    h, w = shape
    tile = tile_rows if h % tile_rows == 0 else 1
    n_tiles = h // tile
    total = float(h * w)

    def kernel(x_ref, o_ref):
        r = pl.program_id(0)
        v = x_ref[...].astype(jnp.float32)
        tmax = jnp.max(v)
        tmin = jnp.min(v)
        tsum = jnp.sum(v)

        @pl.when(r == 0)
        def _init():
            o_ref[0] = tmax
            o_ref[1] = tmin
            o_ref[2] = tsum
            o_ref[3] = tsum / total

        @pl.when(r != 0)
        def _fold():
            o_ref[0] = jnp.maximum(o_ref[0], tmax)
            o_ref[1] = jnp.minimum(o_ref[1], tmin)
            s = o_ref[2] + tsum
            o_ref[2] = s
            o_ref[3] = s / total

    def f(x):
        return pl.pallas_call(
            kernel,
            grid=(n_tiles,),
            in_specs=[pl.BlockSpec((tile, w), lambda r: (r, 0))],
            out_specs=pl.BlockSpec((4,), lambda r: (0,)),
            out_shape=jax.ShapeDtypeStruct((4,), jnp.float32),
            interpret=True,
        )(x)

    return f
