"""InterpDPP — the generic runtime-fusion kernel.

The paper achieves "any combination of library functions fuses" through C++
template instantiation at the *user's* compile time. Our runtime is a
self-contained Rust binary with no Python/JAX available, so arbitrary chains
cannot trigger a fresh AOT lowering on the request path. This kernel is the
substitution (DESIGN.md §3.6): ONE artifact whose op chain is a runtime input.

The opcode vector (i32[K]) and parameter vector (f32[K]) drive a
``lax.switch`` inside a ``fori_loop`` *inside the Pallas kernel body*, so the
whole interpreted chain still executes in one launch with the running value
held in registers — Vertical Fusion with a dynamic program. Unused slots are
``nop`` (opcode 0). The Rust fusion planner falls back to this tier whenever
no exact or StaticLoop artifact matches the user's pipeline.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from compile.opcodes import DTYPES, N_OPS, cast_in, cast_out, switch_branches


def make_interp(kmax, shape, batch, dtin, dtout):
    """Build the interpreter kernel.

    Returns ``f(x, opcodes, params) -> y`` with x: dtin[batch, *shape],
    opcodes: i32[kmax], params: f32[kmax].
    """
    branches = switch_branches()

    def kernel(x_ref, opc_ref, par_ref, o_ref):
        v = cast_in(x_ref[...], dtin, dtout)

        def body(i, v):
            op = jnp.clip(opc_ref[i], 0, N_OPS - 1)
            return lax.switch(op, branches, v, par_ref[i].astype(v.dtype))

        v = lax.fori_loop(0, kmax, body, v)
        o_ref[...] = cast_out(v, dtin, dtout)

    # whole-array single program (see transform.make_chain PERF note)
    def f(x, opcodes, params):
        return pl.pallas_call(
            kernel,
            out_shape=jax.ShapeDtypeStruct((batch,) + tuple(shape), DTYPES[dtout]),
            interpret=True,
        )(x, opcodes, params)

    return f
