"""L2 — artifact builders: every AOT-compiled computation in the system.

Each builder returns ``(fn, arg_specs, manifest_entry)``:

* ``fn``        — a jax-jittable callable (calls the L1 Pallas kernels for the
                  ``pallas`` variant, or the pure-jnp oracles for the ``xla``
                  variant used in the lowering ablation),
* ``arg_specs`` — ShapeDtypeStructs to lower against (argument order == the
                  order the Rust runtime feeds inputs at execute time),
* ``manifest_entry`` — the metadata the Rust artifact Registry indexes on.

The artifact *family* (which shapes/batches/chains get pre-AOT'd) is declared
in :mod:`compile.aot`; this module only knows how to build one of each kind.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from compile.opcodes import DTYPES
from compile.kernels import interp as k_interp
from compile.kernels import preproc as k_preproc
from compile.kernels import reduce as k_reduce
from compile.kernels import ref as k_ref
from compile.kernels import transform as k_transform

F32 = jnp.float32
I32 = jnp.int32
U8 = jnp.uint8


def _sds(shape, dt):
    return jax.ShapeDtypeStruct(tuple(shape), dt)


def _inp(role, dtype, shape):
    return {"role": role, "dtype": dtype, "shape": list(shape)}


def _shape_tag(shape):
    return "x".join(str(s) for s in shape)


def chain_name(ops, dtin, dtout, shape, batch, variant, kind="chain"):
    return f"{kind}_{'-'.join(ops)}_{dtin}2{dtout}_{_shape_tag(shape)}_b{batch}_{variant}"


def build_chain(ops, shape, batch, dtin, dtout, variant="pallas", channel_params=False, kind=None):
    """Fused op chain (VF; batch > 1 adds HF). kind defaults to single_op for
    1-op chains — those are the unfused-baseline vocabulary."""
    kind = kind or ("single_op" if len(ops) == 1 else "chain")
    k = len(ops)
    pshape = (k, 3) if channel_params else (k,)
    full = (batch,) + tuple(shape)

    if variant == "pallas":
        f = k_transform.make_chain(ops, shape, batch, dtin, dtout, channel_params)
    else:
        f = functools.partial(k_ref.chain_ref, ops=ops, dtin=dtin, dtout=dtout)

    specs = [_sds(full, DTYPES[dtin]), _sds(pshape, F32)]
    entry = {
        "name": chain_name(ops, dtin, dtout, shape, batch, variant, kind),
        "kind": kind,
        "variant": variant,
        "ops": list(ops),
        "dtin": dtin,
        "dtout": dtout,
        "shape": list(shape),
        "batch": batch,
        "channel_params": channel_params,
        "inputs": [_inp("data", dtin, full), _inp("params", "f32", pshape)],
        "output": {"dtype": dtout, "shape": list(full)},
    }
    return f, specs, entry


def build_staticloop(ops, shape, batch, dtin, dtout, variant="pallas"):
    """Chain body repeated a runtime number of times (arg 0: i32[1])."""
    k = len(ops)
    full = (batch,) + tuple(shape)

    if variant == "pallas":
        f = k_transform.make_staticloop(ops, shape, batch, dtin, dtout)
    else:

        def f(iters, x, params):
            return k_ref.staticloop_ref(x, params, iters[0], ops, dtin, dtout)

    specs = [_sds((1,), I32), _sds(full, DTYPES[dtin]), _sds((k,), F32)]
    entry = {
        "name": chain_name(ops, dtin, dtout, shape, batch, variant, "staticloop"),
        "kind": "staticloop",
        "variant": variant,
        "ops": list(ops),
        "dtin": dtin,
        "dtout": dtout,
        "shape": list(shape),
        "batch": batch,
        "inputs": [
            _inp("trip", "i32", (1,)),
            _inp("data", dtin, full),
            _inp("params", "f32", (k,)),
        ],
        "output": {"dtype": dtout, "shape": list(full)},
    }
    return f, specs, entry


def build_interp(kmax, shape, batch, dtin, dtout, variant="pallas"):
    """Generic interpreter kernel: runtime opcode/param vectors (tier 3)."""
    full = (batch,) + tuple(shape)
    if variant == "pallas":
        f = k_interp.make_interp(kmax, shape, batch, dtin, dtout)
    else:

        def f(x, opcodes, params):
            from compile.opcodes import cast_in, cast_out

            v = cast_in(x, dtin, dtout)
            v = k_ref.interp_ref(v, opcodes, params.astype(v.dtype))
            return cast_out(v, dtin, dtout)

    specs = [_sds(full, DTYPES[dtin]), _sds((kmax,), I32), _sds((kmax,), F32)]
    entry = {
        "name": f"interp_k{kmax}_{dtin}2{dtout}_{_shape_tag(shape)}_b{batch}_{variant}",
        "kind": "interp",
        "variant": variant,
        "ops": [],
        "kmax": kmax,
        "dtin": dtin,
        "dtout": dtout,
        "shape": list(shape),
        "batch": batch,
        "inputs": [
            _inp("data", dtin, full),
            _inp("opcodes", "i32", (kmax,)),
            _inp("params", "f32", (kmax,)),
        ],
        "output": {"dtype": dtout, "shape": list(full)},
    }
    return f, specs, entry


def build_preproc(frame_shape, batch, dh, dw, variant="pallas"):
    """Fused production pipeline: Batch(Crop->Resize->ColorConvert->Mul->Sub->Div->Split)."""
    if variant == "pallas":
        f = k_preproc.make_preproc(frame_shape, batch, dh, dw)
    else:

        def f(frame, rects, mulv, subv, divv):
            return k_ref.preproc_ref(frame, rects, mulv, subv, divv, dh, dw)

    specs = [
        _sds(frame_shape, U8),
        _sds((batch, 4), I32),
        _sds((3,), F32),
        _sds((3,), F32),
        _sds((3,), F32),
    ]
    entry = {
        "name": f"preproc_{_shape_tag(frame_shape)}_to{dh}x{dw}_b{batch}_{variant}",
        "kind": "preproc",
        "variant": variant,
        "ops": ["crop", "resize", "cvtcolor", "mul", "sub", "div", "split"],
        "dtin": "u8",
        "dtout": "f32",
        "shape": [dh, dw],
        "frame_shape": list(frame_shape),
        "batch": batch,
        "inputs": [
            _inp("frame", "u8", frame_shape),
            _inp("rects", "i32", (batch, 4)),
            _inp("vec3", "f32", (3,)),
            _inp("vec3", "f32", (3,)),
            _inp("vec3", "f32", (3,)),
        ],
        "output": {"dtype": "f32", "shape": [batch, 3, dh, dw]},
    }
    return f, specs, entry


def build_preproc_step(step, frame_shape, src_h, src_w, dh, dw):
    """One UNFUSED pipeline step (the OpenCV-CUDA/NPP baseline vocabulary).

    Steps: crop (dynamic_slice from the frame), convert, resize, cvtcolor,
    mulc, subc, divc, split — each its own executable, each a full memory pass.
    """
    steps = k_preproc.make_single_steps(dh, dw, src_h, src_w)

    if step == "crop":

        def f(frame, rect):
            zero = jnp.zeros((), rect.dtype)
            return jax.lax.dynamic_slice(frame, (rect[1], rect[0], zero), (src_h, src_w, 3))

        specs = [_sds(frame_shape, U8), _sds((4,), I32)]
        inputs = [_inp("frame", "u8", frame_shape), _inp("rect", "i32", (4,))]
        out = {"dtype": "u8", "shape": [src_h, src_w, 3]}
    elif step == "convert":
        f = steps["convert"]
        specs = [_sds((src_h, src_w, 3), U8)]
        inputs = [_inp("data", "u8", (src_h, src_w, 3))]
        out = {"dtype": "f32", "shape": [src_h, src_w, 3]}
    elif step == "resize":
        f = steps["resize"]
        specs = [_sds((src_h, src_w, 3), F32)]
        inputs = [_inp("data", "f32", (src_h, src_w, 3))]
        out = {"dtype": "f32", "shape": [dh, dw, 3]}
    elif step == "cvtcolor":
        f = steps["cvtcolor"]
        specs = [_sds((dh, dw, 3), F32)]
        inputs = [_inp("data", "f32", (dh, dw, 3))]
        out = {"dtype": "f32", "shape": [dh, dw, 3]}
    elif step in ("mulc", "subc", "divc"):
        f = steps[step]
        specs = [_sds((dh, dw, 3), F32), _sds((3,), F32)]
        inputs = [_inp("data", "f32", (dh, dw, 3)), _inp("vec3", "f32", (3,))]
        out = {"dtype": "f32", "shape": [dh, dw, 3]}
    elif step == "split":
        f = steps["split"]
        specs = [_sds((dh, dw, 3), F32)]
        inputs = [_inp("data", "f32", (dh, dw, 3))]
        out = {"dtype": "f32", "shape": [3, dh, dw]}
    else:
        raise ValueError(step)

    entry = {
        "name": f"prestep_{step}_{src_h}x{src_w}_to{dh}x{dw}",
        "kind": "preproc_step",
        "variant": "xla",
        "step": step,
        "ops": [step],
        "dtin": inputs[0]["dtype"],
        "dtout": out["dtype"],
        "shape": out["shape"],
        "batch": 1,
        "inputs": inputs,
        "output": out,
    }
    return f, specs, entry


def build_reduce_stats(shape, dtin, variant="pallas"):
    """One-pass (max, min, sum, mean) ReduceDPP artifact."""
    if variant == "pallas":
        f = k_reduce.make_reduce_stats(shape, dtin)
    else:
        f = k_ref.reduce_stats_ref
    specs = [_sds(shape, DTYPES[dtin])]
    entry = {
        "name": f"reduce_stats_{dtin}_{_shape_tag(shape)}_{variant}",
        "kind": "reduce",
        "variant": variant,
        "ops": ["max", "min", "sum", "mean"],
        "dtin": dtin,
        "dtout": "f32",
        "shape": list(shape),
        "batch": 1,
        "inputs": [_inp("data", dtin, shape)],
        "output": {"dtype": "f32", "shape": [4]},
    }
    return f, specs, entry
