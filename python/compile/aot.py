"""AOT driver: lower the whole artifact family to HLO text + manifest.json.

Python runs exactly once, at build time (``make artifacts``); the Rust
coordinator is self-contained afterwards. Interchange is HLO **text** — NOT
``lowered.compiler_ir("hlo")`` protos or ``.serialize()`` — because jax >= 0.5
emits HloModuleProto with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

The artifact family below is the system's "pre-instantiated template set":
the analog of every template instantiation the paper's C++ compiler would
produce for the evaluation section, plus the generic interpreter artifacts
that cover chains with no exact match (DESIGN.md §3.6, §5).

Experiment scale: paper-scale images (4096x2160, 66M-element vectors) make
CPU baseline sweeps take hours; the default family is scaled down (documented
in EXPERIMENTS.md) and ``--paper-scale`` restores the full sizes.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax

# f64 artifacts (Fig. 23 dtype combos) require real double support; without
# this flag jax silently computes them in f32.
jax.config.update("jax_enable_x64", True)

from compile import model
from compile.opcodes import OPS

# ---------------------------------------------------------------------------
# Experiment geometry (single source of truth; the manifest carries it to Rust)
# ---------------------------------------------------------------------------

SCALED = {
    # xp02 VF sweep image (paper: 4096x2160 u8)
    "vf_shape": (512, 1024),
    # fig1 / xp05 1-D vector (paper: 3840*2160*8 = 66.3M f32)
    "vec_n": 4_194_304,
    # xp07 data-size sweep (paper: 100 .. 16,654,030) — kept, it is feasible
    "sizes": [100, 1_000, 10_000, 100_000, 282_370, 1_000_000, 3_000_000, 9_032_740, 16_654_030],
}
PAPER = {
    "vf_shape": (2160, 4096),
    "vec_n": 66_355_200,
    "sizes": SCALED["sizes"],
}

# HF batch buckets (paper sweeps 1..1,191 by tens; log-spaced buckets here,
# the Rust HF planner pads to the next bucket and accounts the pad)
HF_BATCHES = [1, 2, 4, 8, 16, 25, 50, 100, 150, 200, 300, 400, 600]
# preprocessing pipeline batch buckets (paper: 2..152)
PREPROC_BATCHES = [2, 8, 16, 32, 50, 64, 100, 128, 152]
# dtype in->out combos of Fig. 23
DTYPE_COMBOS = [
    ("u8", "u8"),
    ("u8", "f32"),
    ("u16", "f32"),
    ("f32", "f32"),
    ("f32", "f64"),
    ("f64", "f64"),
    ("u8", "f64"),
    ("f32", "u8"),
]
# the Fig. 17/23 per-element chain: Cast -> Mul -> Sub -> Div
CMSD = ["nop", "mul", "sub", "div"]
# production pipeline geometry (paper: 60x120 crops resized to 64x128)
FRAME_SHAPE = (720, 1280, 3)
CROP_H, CROP_W = 60, 120
DST_H, DST_W = 128, 64
INTERP_KMAX = 16


def family(scale):
    """Yield (builder_fn, args, kwargs) for every artifact in the family."""
    g = []
    vf_shape = scale["vf_shape"]
    vec_n = scale["vec_n"]

    # -- vertical-slice smoke artifact (tiny; used by rust integration tests)
    g.append((model.build_chain, (["mul", "add"], (4, 8), 2, "f32", "f32"), {}))
    g.append((model.build_chain, (["mul", "add"], (4, 8), 2, "f32", "f32"), {"variant": "xla"}))

    # -- Fig. 1 / xp05: staticloop over a flat f32 vector, runtime trip count
    g.append((model.build_staticloop, (["mul"], (vec_n,), 1, "f32", "f32"), {}))
    g.append((model.build_staticloop, (["mul", "add"], (vec_n,), 1, "f32", "f32"), {}))
    g.append((model.build_staticloop, (["mul", "add"], (vec_n,), 1, "f32", "f32"), {"variant": "xla"}))

    # -- xp02: VF sweep on the big u8 image — fused staticloop + unfused per-op
    for ops in (["mul"], ["mul", "add"]):
        g.append((model.build_staticloop, (ops, vf_shape, 1, "u8", "u8"), {}))
    for op in ("mul", "add"):
        g.append((model.build_chain, ([op], vf_shape, 1, "u8", "u8"), {}))

    # -- xp03: HF sweep — the CMSD chain at every batch bucket
    for b in HF_BATCHES:
        g.append((model.build_chain, (CMSD, (CROP_H, CROP_W), b, "u8", "f32"), {}))

    # -- xp04: VF x HF — staticloop muladd at batch 50 + per-op baselines
    g.append((model.build_staticloop, (["mul", "add"], (CROP_H, CROP_W), 50, "u8", "u8"), {}))
    for op in ("mul", "add"):
        g.append((model.build_chain, ([op], (CROP_H, CROP_W), 1, "u8", "u8"), {}))

    # -- xp07: data-size sweep — staticloop muladd per size bucket, plus the
    #    per-op singles the unfused baseline launches (one kernel per op)
    for n in scale["sizes"]:
        g.append((model.build_staticloop, (["mul", "add"], (n,), 1, "f32", "f32"), {}))
        for op in ("mul", "add"):
            g.append((model.build_chain, ([op], (n,), 1, "f32", "f32"), {}))

    # -- xp09: dtype combos of the CMSD chain at batch 50
    for dtin, dtout in DTYPE_COMBOS:
        g.append((model.build_chain, (CMSD, (CROP_H, CROP_W), 50, dtin, dtout), {}))
        # unfused per-op vocabulary in matching dtypes (each step io in dtout
        # domain after the cast step, like OpenCV convertTo + arithm calls)
        g.append((model.build_chain, (["nop"], (CROP_H, CROP_W), 1, dtin, dtout), {}))
        for op in ("mul", "sub", "div"):
            g.append((model.build_chain, ([op], (CROP_H, CROP_W), 1, dtout, dtout), {}))

    # -- ablation: same CMSD chain, XLA-lowered (no Pallas structure)
    g.append((model.build_chain, (CMSD, (CROP_H, CROP_W), 50, "u8", "f32"), {"variant": "xla"}))

    # -- xp06/xp10: fused preprocessing pipeline per batch bucket + step vocab
    for b in PREPROC_BATCHES:
        g.append((model.build_preproc, (FRAME_SHAPE, b, DST_H, DST_W), {}))
    g.append((model.build_preproc, (FRAME_SHAPE, 2, DST_H, DST_W), {"variant": "xla"}))
    for step in ("crop", "convert", "resize", "cvtcolor", "mulc", "subc", "divc", "split"):
        g.append((model.build_preproc_step, (step, FRAME_SHAPE, CROP_H, CROP_W, DST_H, DST_W), {}))

    # -- interpreter artifacts (generic runtime fusion, tier 3)
    g.append((model.build_interp, (INTERP_KMAX, (CROP_H, CROP_W), 50, "u8", "f32"), {}))
    g.append((model.build_interp, (INTERP_KMAX, (256, 256), 1, "f32", "f32"), {}))
    g.append((model.build_interp, (INTERP_KMAX, (256, 256), 1, "f32", "f32"), {"variant": "xla"}))

    # -- ReduceDPP artifact
    g.append((model.build_reduce_stats, ((512, 512), "f32"), {}))
    g.append((model.build_reduce_stats, ((512, 512), "f32"), {"variant": "xla"}))

    return g


def to_hlo_text(fn, specs) -> str:
    """jit -> lower -> stablehlo -> XlaComputation -> HLO text."""
    from jax._src.lib import xla_client as xc

    lowered = jax.jit(fn).lower(*specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    # return_tuple=False: every artifact has exactly one output, and a plain
    # array root lets the Rust side chain device-resident buffers between
    # executables (a tuple root would interpose an 8-byte tuple index buffer
    # that PJRT cannot feed to the next executable's array parameter).
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    return comp.as_hlo_text()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--paper-scale", action="store_true", help="full paper sizes")
    ap.add_argument("--only", default=None, help="substring filter on artifact names")
    ap.add_argument("--force", action="store_true", help="rebuild even if file exists")
    args = ap.parse_args()

    scale = PAPER if args.paper_scale else SCALED
    outdir = os.path.abspath(args.out)
    os.makedirs(outdir, exist_ok=True)

    entries = []
    built = skipped = 0
    for builder, bargs, bkwargs in family(scale):
        fn, specs, entry = builder(*bargs, **bkwargs)
        name = entry["name"]
        if args.only and args.only not in name:
            continue
        fname = f"{name}.hlo.txt"
        path = os.path.join(outdir, fname)
        entry["file"] = fname
        if os.path.exists(path) and not args.force:
            skipped += 1
        else:
            text = to_hlo_text(fn, specs)
            with open(path, "w") as f:
                f.write(text)
            built += 1
            print(f"  [{built:3d}] {name} ({len(text)} chars)")
        entry["sha256"] = hashlib.sha256(open(path, "rb").read()).hexdigest()[:16]
        entries.append(entry)

    manifest = {
        "version": 1,
        "scale": "paper" if args.paper_scale else "scaled",
        "opcodes": {name: code for name, (code, _) in OPS.items()},
        "geometry": {
            "vf_shape": list(scale["vf_shape"]),
            "vec_n": scale["vec_n"],
            "sizes": scale["sizes"],
            "hf_batches": HF_BATCHES,
            "preproc_batches": PREPROC_BATCHES,
            "dtype_combos": [list(c) for c in DTYPE_COMBOS],
            "frame_shape": list(FRAME_SHAPE),
            "crop": [CROP_H, CROP_W],
            "dst": [DST_H, DST_W],
            "interp_kmax": INTERP_KMAX,
        },
        "artifacts": entries,
    }
    mpath = os.path.join(outdir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {mpath}: {len(entries)} artifacts ({built} built, {skipped} cached)")


if __name__ == "__main__":
    main()
